//! One-pass compiler from IR to slot-resolved register programs.
//!
//! The reference interpreter (`local.rs`) re-walks the `Expr` tree for
//! every row and resolves every variable, cursor field and accumulator
//! array by string comparison. This module performs all of that name
//! resolution **once**: expressions become flat register programs
//! ([`ExprProg`]) whose operands are integer slots, and statements become
//! [`CStmt`] trees whose loops carry pre-resolved tables, field ids and
//! (when the body is a recognized single-statement aggregation) a fused
//! batch kernel tag ([`FastAgg`]). The vectorized executor (`vector.rs`)
//! then drives the compiled form in column batches; the dense inner
//! loops behind those kernel tags (selection-vector equality filters,
//! fused count/sum aggregation) are SIMD-shaped `chunks_exact` bodies
//! that tag `vec.simd` when the reshaped path fires.
//!
//! Join-shaped programs compile too: the Figure-1 nested `forelem` with a
//! filtered inner index set (`forelem i ∈ pA { forelem j ∈ pB.id[i.b_id]
//! { ... } }`, the exact form `sql::lower` emits for equi-joins) becomes a
//! [`JoinLoop`] — a build+probe hash join the vectorized executor drives
//! with the same selection-vector and slot-resolved-register machinery as
//! plain scans. Deeper filtered levels (the N-way star/snowflake chains
//! `sql::lower` emits for 3+-table joins, possibly reordered by the
//! optimizer) are absorbed as [`JoinLevel`]s: one hash table per joined
//! table, probed level by level per matched row. Single-statement
//! aggregation bodies over the matched pairs of a two-table join
//! (join + GROUP BY) carry a fused [`JoinFastAgg`] kernel tag.
//!
//! Compilation is *total or nothing*: [`compile_program`] returns `None`
//! for any program shape outside the supported tier (data loops nested
//! deeper than the join shape, value partitions, distinct-value domains,
//! assignments that the interpreter's scope stack would treat subtly
//! differently), so the dispatch in `plan.rs` can fall back to the
//! interpreter and observable behaviour — including error behaviour — is
//! preserved exactly.

use std::sync::Arc;

use crate::ir::{
    AccumOp, BinOp, Domain, EmitOrder, Expr, IndexSet, Loop, LoopKind, Program, Schema, SlotMap,
    Stmt, Strategy, TopKStrategy, UnOp, Value,
};
use crate::storage::{StorageCatalog, Table};

/// A flat register program for one expression. Ops write to registers;
/// the value of the expression ends up in `out`.
#[derive(Debug, Clone)]
pub struct ExprProg {
    pub ops: Vec<Op>,
    /// Registers used by this program (including any nested `Sum` body).
    pub n_regs: usize,
    /// Register holding the final value.
    pub out: usize,
}

/// One register operation. All names are resolved: `slot` indexes the
/// scalar slot table, `cursor`/`field` index cursor slots and table
/// columns, `array` indexes the accumulator-array table.
#[derive(Debug, Clone)]
pub enum Op {
    Const { dst: usize, v: Value },
    LoadScalar { dst: usize, slot: usize },
    /// Late-bound program parameter: `param` indexes
    /// [`CompiledProgram::param_names`]. Kept a runtime load (not folded
    /// to a `Const`) so one compiled program serves every prepared-
    /// statement binding.
    LoadParam { dst: usize, param: usize },
    LoadField { dst: usize, cursor: usize, field: usize },
    ReadArray { dst: usize, array: usize, idx: Vec<usize> },
    Binary { dst: usize, op: BinOp, lhs: usize, rhs: usize },
    Unary { dst: usize, op: UnOp, src: usize },
    /// `regs[dst] = Bool(regs[src].truthy())` — the && / || result form.
    Truthy { dst: usize, src: usize },
    /// Skip the next `n` ops when `regs[src]` is truthy (|| short-circuit).
    SkipIfTrue { src: usize, n: usize },
    /// Skip the next `n` ops when `regs[src]` is falsy (&& short-circuit).
    SkipIfFalse { src: usize, n: usize },
    /// `Σ_{k=1}^{parts} body` with `k` bound to scalar `slot` — the
    /// cross-partition reduction of §IV.
    Sum {
        dst: usize,
        slot: usize,
        parts: usize,
        body: Box<ExprProg>,
    },
}

/// A compiled statement.
#[derive(Debug, Clone)]
pub enum CStmt {
    Assign { slot: usize, value: ExprProg },
    Accum {
        array: usize,
        idx: Vec<ExprProg>,
        op: AccumOp,
        value: ExprProg,
    },
    Result { result: usize, tuple: Vec<ExprProg> },
    If {
        cond: ExprProg,
        then: Vec<CStmt>,
        els: Vec<CStmt>,
    },
    Print { format: String, args: Vec<ExprProg> },
    /// Integer range loop (`for` / `forall` over a range). `forall` runs
    /// sequentially here; `exec::parallel` fans top-level ones out.
    Range {
        kind: LoopKind,
        slot: usize,
        lo: ExprProg,
        hi: ExprProg,
        body: Vec<CStmt>,
    },
    Scan(ScanLoop),
    Join(JoinLoop),
}

/// Compiled form of the IR's ordered/bounded emission contract
/// ([`EmitOrder`]): the loop's appended result rows are re-emitted
/// sorted by tuple position `key` and bounded to `limit`.
#[derive(Debug, Clone)]
pub struct EmitSpec {
    /// Result tuple position to sort by (`None` = bare `LIMIT`).
    pub key: Option<usize>,
    pub descending: bool,
    pub limit: Option<usize>,
    /// True when the bounded-heap `vec.topk` kernel executes this
    /// emission (O(n log k), memory O(k)); false materializes + sorts.
    /// Resolved from the optimizer's [`TopKStrategy`] decision: a
    /// bounded emission defaults to the heap unless `opt.topk_sort`
    /// said otherwise.
    pub heap: bool,
}

impl EmitSpec {
    fn from_ir(e: &EmitOrder) -> EmitSpec {
        EmitSpec {
            key: e.key,
            descending: e.descending,
            limit: e.limit,
            heap: e.limit.is_some() && e.strategy != TopKStrategy::Sort,
        }
    }
}

/// A compiled `forelem` loop over an index set: the unit the vectorized
/// executor drives in column batches.
#[derive(Debug, Clone)]
pub struct ScanLoop {
    pub table: Arc<Table>,
    /// Cursor slot the loop variable binds.
    pub cursor: usize,
    /// `pA.field[v]` equality filter: (field id, key expression). The key
    /// is evaluated once per loop entry, in the enclosing scope.
    pub filter: Option<(usize, ExprProg)>,
    /// `pA.distinct(field)`: iterate one representative row per distinct
    /// value of this field. When set, `filter` is ignored (interpreter
    /// parity: the distinct branch takes precedence).
    pub distinct: Option<usize>,
    /// Direct partition restriction: (part, parts) expressions.
    pub partition: Option<(ExprProg, ExprProg)>,
    pub body: Vec<CStmt>,
    /// Whole-loop fused aggregation, when the body is a recognized
    /// single-statement accumulation. The generic `body` is kept too: the
    /// fast path only fires when its target array is empty at loop entry
    /// (so float fold order matches the interpreter exactly).
    pub fast: Option<FastAgg>,
    /// Ordered/bounded emission contract for this loop's result rows
    /// (`ORDER BY`/`LIMIT`): appends are intercepted into a `TopK`
    /// accumulator and re-emitted sorted/bounded at loop exit.
    pub emit: Option<EmitSpec>,
}

/// Recognized single-statement batch aggregations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FastAgg {
    /// `count[i.key]++` with integer-zero init.
    Count { array: usize, key_field: usize },
    /// `sum[i.key] += i.val` with zero init and a numeric value column.
    Sum {
        array: usize,
        key_field: usize,
        val_field: usize,
    },
}

impl FastAgg {
    /// Slot of the accumulator array this aggregation targets.
    pub fn array(self) -> usize {
        match self {
            FastAgg::Count { array, .. } | FastAgg::Sum { array, .. } => array,
        }
    }
}

/// One level of a compiled join chain below the first build side: a
/// further filtered `forelem` whose key expression may reference any
/// enclosing cursor (star keys load from the probe cursor, snowflake
/// keys from an earlier build cursor). Each level's table is hashed
/// once per nest entry and probed per matched row of the level above.
#[derive(Debug, Clone)]
pub struct JoinLevel {
    /// Table this level builds a hash table over.
    pub build: Arc<Table>,
    /// Cursor slot this level's loop variable binds.
    pub cursor: usize,
    /// Field of `build` the hash table is keyed on.
    pub build_key: usize,
    /// Probe key, evaluated per matched row of the enclosing levels with
    /// all enclosing cursors (but not this one) in scope.
    pub probe_key: ExprProg,
}

/// A compiled equi-join: the Figure-1 nested-`forelem`-with-filtered-inner
/// shape, executed as build + probe instead of nested scans. The inner
/// (build) table is hashed once on [`JoinLoop::build_key`]; the outer
/// (probe) side streams through in column batches, each row's probe key
/// selecting the bucket of matching build rows. Buckets preserve table
/// order, so the (outer-major, inner-in-table-order) match sequence is
/// exactly the interpreter's nested-loop order — results, prints and
/// float fold order all stay identical.
///
/// N-way chains (the `sql::lower` star/snowflake nest, possibly reordered
/// by the optimizer's `opt.join_order` pass) extend the two-table shape
/// with [`JoinLoop::deeper`]: every level hashes its table once, and each
/// match at level *k* probes level *k+1*, so the whole chain pipelines
/// without materializing intermediate join results.
#[derive(Debug, Clone)]
pub struct JoinLoop {
    /// Probe (outer) side table.
    pub outer: Arc<Table>,
    /// Cursor slot the outer loop variable binds.
    pub outer_cursor: usize,
    /// Equality filter on the outer scan, as in [`ScanLoop::filter`].
    pub outer_filter: Option<(usize, ExprProg)>,
    /// Direct partition restriction of the outer scan: (part, parts).
    pub partition: Option<(ExprProg, ExprProg)>,
    /// Build (inner) side table — the hash table is built over this side.
    pub build: Arc<Table>,
    /// Cursor slot the inner loop variable binds.
    pub build_cursor: usize,
    /// Field of `build` the hash table is keyed on.
    pub build_key: usize,
    /// Probe key, evaluated once per outer row with the outer cursor (but
    /// not the inner one) in scope — interpreter parity for the inner
    /// index set's filter expression.
    pub probe_key: ExprProg,
    /// When the probe key is a plain outer-cursor field load, its field
    /// id — executors then read the probe column directly instead of
    /// running the register program per row.
    pub probe_field: Option<usize>,
    /// Join levels below the first build side, outermost first. Empty for
    /// the plain two-table join.
    pub deeper: Vec<JoinLevel>,
    /// Per-match body, with every chain cursor in scope.
    pub body: Vec<CStmt>,
    /// Fused per-match aggregation (join + GROUP BY shapes). Subject to
    /// the same empty-array entry guard as [`ScanLoop::fast`].
    pub fast: Option<JoinFastAgg>,
    /// Ordered/bounded emission contract covering the whole nest's
    /// appended rows, as in [`ScanLoop::emit`].
    pub emit: Option<EmitSpec>,
}

/// Which side of a compiled join a fused-aggregation column lives on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinSide {
    /// The probe (outer) table.
    Outer,
    /// The build (inner) table.
    Build,
}

/// Recognized single-statement per-match aggregations of a join body:
/// the `SELECT g, AGG(x) ... JOIN ... GROUP BY g` accumulation loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinFastAgg {
    /// `count[key]++` per matched pair, with integer-zero init.
    Count {
        array: usize,
        key_side: JoinSide,
        key_field: usize,
    },
    /// `sum[key] += val` per matched pair, with zero init and a numeric
    /// value column; key and value may live on either side.
    Sum {
        array: usize,
        key_side: JoinSide,
        key_field: usize,
        val_side: JoinSide,
        val_field: usize,
    },
}

/// A whole program compiled to slot-resolved form. Shareable across
/// threads (`Arc<CompiledProgram>` in `exec::parallel`).
#[derive(Debug, Clone)]
pub struct CompiledProgram {
    /// Names backing the slots (for `Output` export).
    pub slots: SlotMap,
    /// Initial values per scalar slot. The first
    /// `slots.scalars.len()` entries are the declared scalars (exported
    /// on finish); later entries are loop variables and synthesized
    /// assignment targets.
    pub scalar_inits: Vec<Value>,
    /// Initial element value per accumulator array slot.
    pub array_inits: Vec<Value>,
    /// Result schema per result slot.
    pub result_schemas: Vec<Schema>,
    pub n_cursors: usize,
    /// Maximum register count over all expression programs.
    pub n_regs: usize,
    /// Program parameter names in slot order (`Op::LoadParam` indexes
    /// this), i.e. `Program::params` key order.
    pub param_names: Vec<String>,
    /// The parameter values the program was compiled with — the default
    /// binding; executors override per run for prepared statements.
    pub param_inits: Vec<Value>,
    pub body: Vec<CStmt>,
}

/// True when `p` never reads accumulator-array state (directly or via a
/// cross-partition `Sum`). A parallel worker evaluating such a read would
/// observe its own partial accumulator instead of the global one.
pub fn expr_parallel_safe(p: &ExprProg) -> bool {
    p.ops
        .iter()
        .all(|o| !matches!(o, Op::ReadArray { .. } | Op::Sum { .. }))
}

/// True when a compiled loop body's only effects are commutative
/// accumulator adds and result appends — exactly the effects
/// `VecState::absorb` merges losslessly across workers. Scalar
/// assignments, prints and nested loops are rejected.
pub fn body_parallel_safe(body: &[CStmt]) -> bool {
    body.iter().all(|s| match s {
        CStmt::Result { tuple, .. } => tuple.iter().all(expr_parallel_safe),
        CStmt::Accum { idx, op, value, .. } => {
            *op == AccumOp::Add && idx.iter().all(expr_parallel_safe) && expr_parallel_safe(value)
        }
        CStmt::If { cond, then, els } => {
            expr_parallel_safe(cond) && body_parallel_safe(then) && body_parallel_safe(els)
        }
        _ => false,
    })
}

/// True when a compiled scan can execute as morsel-driven parallel
/// batches: no distinct iteration (the distinct index probe is a
/// whole-table concern), no explicit partition restriction (the
/// program is already managing its own distribution), and no emission
/// contract (ordered/bounded emission has its own top-k fan-out, see
/// [`emit_parallel_safe`]), with a [`body_parallel_safe`] body. The
/// equality-filter key needs no check: it is scope-constant and
/// evaluated once in the master's complete pre-loop state, then shared
/// with the workers as a plain value.
pub fn scan_parallel_safe(sl: &ScanLoop) -> bool {
    sl.distinct.is_none()
        && sl.partition.is_none()
        && sl.emit.is_none()
        && body_parallel_safe(&sl.body)
}

/// Join analogue of [`scan_parallel_safe`]: the probe keys (at every
/// chain level) and the outer filter are evaluated *inside* workers (per
/// probe row / per fan-out), so all must be free of accumulator reads.
pub fn join_parallel_safe(jl: &JoinLoop) -> bool {
    jl.partition.is_none()
        && jl.emit.is_none()
        && expr_parallel_safe(&jl.probe_key)
        && jl.deeper.iter().all(|lvl| expr_parallel_safe(&lvl.probe_key))
        && match &jl.outer_filter {
            Some((_, p)) => expr_parallel_safe(p),
            None => true,
        }
        && body_parallel_safe(&jl.body)
}

/// True when an ordered/bounded emit scan can fan out on the morsel pool
/// with per-worker bounded heaps and a k-way merge: the body's only
/// effect is appending result rows (reads of scalars, cursor fields and
/// accumulator arrays are fine — the master's state is complete before
/// the emit loop starts and is snapshotted read-only into each worker).
/// Scalar writes, accumulator writes, prints and nested loops stay on
/// the sequential driver.
pub fn emit_parallel_safe(sl: &ScanLoop) -> bool {
    fn body_ok(body: &[CStmt]) -> bool {
        body.iter().all(|s| match s {
            CStmt::Result { .. } => true,
            CStmt::If { then, els, .. } => body_ok(then) && body_ok(els),
            _ => false,
        })
    }
    matches!(&sl.emit, Some(e) if e.heap) && sl.partition.is_none() && body_ok(&sl.body)
}

/// True when an **unbounded** distinct-emission scan (the group-by emit
/// half without ORDER BY/LIMIT) can fan out on the morsel pool: workers
/// run disjoint slices of the distinct-firsts list against a read-only
/// snapshot of the master's complete accumulator state, and the master
/// concatenates the per-chunk row runs in chunk order — which *is* the
/// sequential emission order, so even ordered consumers see identical
/// output. Same body discipline as [`emit_parallel_safe`] (result
/// appends under `If` guards only), but without a bounded heap: rows are
/// kept verbatim, not top-k-merged. Tags `vec.emit_par` on success.
pub fn distinct_emit_parallel_safe(sl: &ScanLoop) -> bool {
    fn body_ok(body: &[CStmt]) -> bool {
        body.iter().all(|s| match s {
            CStmt::Result { .. } => true,
            CStmt::If { then, els, .. } => body_ok(then) && body_ok(els),
            _ => false,
        })
    }
    sl.distinct.is_some()
        && sl.emit.is_none()
        && sl.partition.is_none()
        && body_ok(&sl.body)
}

/// Compile a program against a catalog. Returns `None` when the program
/// uses any construct outside the vectorized tier — callers fall back to
/// the reference interpreter, which preserves observable behaviour
/// (including error messages for invalid programs).
pub fn compile_program(p: &Program, catalog: &StorageCatalog) -> Option<CompiledProgram> {
    let slots = p.slot_map();
    let array_inits = slots
        .arrays
        .iter()
        .map(|name| p.arrays[name].init.clone())
        .collect();
    let result_schemas = slots
        .results
        .iter()
        .map(|name| p.results[name].clone())
        .collect();
    let mut c = Compiler {
        program: p,
        catalog,
        scopes: Vec::new(),
        scalar_inits: Vec::new(),
        slots,
        cursors: Vec::new(),
        n_cursors: 0,
        n_regs: 0,
        no_fresh_binds: 0,
        range_depth: 0,
    };
    for (slot, name) in c.slots.scalars.clone().into_iter().enumerate() {
        c.scalar_inits.push(p.scalars[&name].clone());
        c.scopes.push((name, slot));
    }
    let body = c.stmts(&p.body)?;
    Some(CompiledProgram {
        scalar_inits: c.scalar_inits,
        array_inits,
        result_schemas,
        n_cursors: c.n_cursors,
        n_regs: c.n_regs,
        param_names: p.params.keys().cloned().collect(),
        param_inits: p.params.values().cloned().collect(),
        body,
        slots: c.slots,
    })
}

struct Compiler<'a> {
    program: &'a Program,
    catalog: &'a StorageCatalog,
    /// Compile-time mirror of the interpreter's env stack: innermost last.
    scopes: Vec<(String, usize)>,
    scalar_inits: Vec<Value>,
    slots: SlotMap,
    /// Active forelem cursors: (loop var, table, cursor slot).
    cursors: Vec<(String, Arc<Table>, usize)>,
    n_cursors: usize,
    n_regs: usize,
    /// Depth of contexts (loops, `If` branches) where a fresh assignment
    /// target cannot soundly be pre-allocated a slot.
    no_fresh_binds: usize,
    /// Depth of enclosing range loops (repeat contexts for scans).
    range_depth: usize,
}

impl<'a> Compiler<'a> {
    fn stmts(&mut self, body: &[Stmt]) -> Option<Vec<CStmt>> {
        body.iter().map(|s| self.stmt(s)).collect()
    }

    fn stmt(&mut self, s: &Stmt) -> Option<CStmt> {
        match s {
            Stmt::Assign { var, value } => {
                let value = self.expr_prog(value)?;
                let slot = match self.scopes.iter().rev().find(|(n, _)| n == var) {
                    Some((_, slot)) => *slot,
                    None => {
                        // The interpreter's `set_var` pushes a fresh env
                        // entry at runtime; a compile-time slot would make
                        // the binding unconditionally visible. Only
                        // compile fresh targets in straight-line top-level
                        // code, where the interpreter binds them
                        // unconditionally too (inside loops the push/pop
                        // discipline differs; inside `If` branches the
                        // binding may never happen at runtime).
                        if self.no_fresh_binds > 0 {
                            return None;
                        }
                        let slot = self.scalar_inits.len();
                        self.scalar_inits.push(Value::Null);
                        self.scopes.push((var.clone(), slot));
                        slot
                    }
                };
                Some(CStmt::Assign { slot, value })
            }
            Stmt::Accum {
                array,
                indices,
                op,
                value,
            } => {
                let array = self.slots.array_slot(array)?;
                let idx = indices
                    .iter()
                    .map(|e| self.expr_prog(e))
                    .collect::<Option<Vec<_>>>()?;
                let value = self.expr_prog(value)?;
                Some(CStmt::Accum {
                    array,
                    idx,
                    op: *op,
                    value,
                })
            }
            Stmt::ResultUnion { result, tuple } => {
                let result = self.slots.result_slot(result)?;
                let tuple = tuple
                    .iter()
                    .map(|e| self.expr_prog(e))
                    .collect::<Option<Vec<_>>>()?;
                Some(CStmt::Result { result, tuple })
            }
            Stmt::If { cond, then, els } => {
                let cond = self.expr_prog(cond)?;
                // Branch bodies run conditionally: fresh bindings inside
                // them are unsound to pre-allocate (see Assign above).
                self.no_fresh_binds += 1;
                let then = self.stmts(then);
                let els = self.stmts(els);
                self.no_fresh_binds -= 1;
                Some(CStmt::If {
                    cond,
                    then: then?,
                    els: els?,
                })
            }
            Stmt::Print { format, args } => {
                let args = args
                    .iter()
                    .map(|e| self.expr_prog(e))
                    .collect::<Option<Vec<_>>>()?;
                Some(CStmt::Print {
                    format: format.clone(),
                    args,
                })
            }
            Stmt::Loop(l) => self.compile_loop(l),
        }
    }

    fn compile_loop(&mut self, l: &Loop) -> Option<CStmt> {
        // Ordered/bounded emission is supported on forelem scans and the
        // compiled join nest; a range loop carrying one falls back to the
        // interpreter's reference semantics.
        if l.emit.is_some() && !matches!(&l.domain, Domain::IndexSet(_)) {
            return None;
        }
        match &l.domain {
            Domain::Range { lo, hi } => {
                let lo = self.expr_prog(lo)?;
                let hi = self.expr_prog(hi)?;
                let slot = self.scalar_inits.len();
                self.scalar_inits.push(Value::Null);
                self.scopes.push((l.var.clone(), slot));
                self.no_fresh_binds += 1;
                self.range_depth += 1;
                let body = self.stmts(&l.body);
                self.range_depth -= 1;
                self.no_fresh_binds -= 1;
                self.scopes.pop();
                Some(CStmt::Range {
                    kind: l.kind,
                    slot,
                    lo,
                    hi,
                    body: body?,
                })
            }
            Domain::IndexSet(ix) => {
                // The Figure-1 join shape — an outer scan whose whole body
                // is one inner forelem filtered on a key from the outer
                // cursor, possibly wrapping further filtered levels —
                // compiles to a build+probe hash join chain.
                if self.cursors.is_empty() {
                    if let [Stmt::Loop(inner)] = l.body.as_slice() {
                        if let Some(join) = self.try_compile_join(l, ix, inner) {
                            return Some(join);
                        }
                    }
                }
                // Otherwise one data loop at a time: deeper forelem nests
                // keep the interpreter's index strategies.
                if !self.cursors.is_empty() {
                    return None;
                }
                // A filtered scan the materialization pass gave an index
                // strategy, sitting inside a range loop, would probe a
                // cached hash/tree index once per iteration on the
                // interpreter; vectorizing it as repeated full scans
                // would negate that choice. Leave those on the
                // interpreter tier.
                if ix.field_filter.is_some()
                    && matches!(ix.strategy, Strategy::Hash | Strategy::Tree)
                    && self.range_depth > 0
                {
                    return None;
                }
                let table = self.catalog.get(&ix.relation).ok()?.clone();
                let filter = match &ix.field_filter {
                    Some((field, value)) => {
                        let fid = table.schema.field_id(field)?;
                        Some((fid, self.expr_prog(value)?))
                    }
                    None => None,
                };
                let distinct = match &ix.distinct {
                    Some(field) => Some(table.schema.field_id(field)?),
                    None => None,
                };
                let partition = match &ix.partition {
                    Some(p) => Some((self.expr_prog(&p.part)?, self.expr_prog(&p.parts)?)),
                    None => None,
                };
                let cursor = self.n_cursors;
                self.n_cursors += 1;
                self.cursors.push((l.var.clone(), table.clone(), cursor));
                self.no_fresh_binds += 1;
                let body = self.stmts(&l.body);
                self.no_fresh_binds -= 1;
                self.cursors.pop();
                let body = body?;
                let fast = if filter.is_none() && distinct.is_none() && l.emit.is_none() {
                    self.detect_fast(l, &table)
                } else {
                    None
                };
                Some(CStmt::Scan(ScanLoop {
                    table,
                    cursor,
                    filter,
                    distinct,
                    partition,
                    body,
                    fast,
                    emit: l.emit.as_ref().map(EmitSpec::from_ir),
                }))
            }
            // Indirect (value) partitioning and distinct-value domains
            // stay on the interpreter tier.
            Domain::ValuePartition { .. } | Domain::DistinctValues { .. } => None,
        }
    }

    /// Recognize and compile the Figure-1 join shape:
    ///
    /// ```text
    /// forelem (i; i ∈ pA) { forelem (j; j ∈ pB.id[i.b_id]) { BODY } }
    /// ```
    ///
    /// into a [`JoinLoop`], greedily absorbing further filtered `forelem`
    /// levels (`forelem (j2; j2 ∈ pC.id[…])` wrapping the body) as
    /// [`JoinLevel`]s — the N-way star/snowflake chain. Returns `None`
    /// for shapes outside the supported form (outer distinct, inner
    /// distinct/partition, missing inner filter); the caller then falls
    /// through to the generic paths, which reject nested data loops and
    /// leave the program on the interpreter tier.
    fn try_compile_join(&mut self, outer: &Loop, ox: &IndexSet, inner: &Loop) -> Option<CStmt> {
        let Domain::IndexSet(iix) = &inner.domain else {
            return None;
        };
        let (ifield, ikey) = iix.field_filter.as_ref()?;
        if ox.distinct.is_some() || iix.distinct.is_some() || iix.partition.is_some() {
            return None;
        }
        // An emission contract on the inner loop would bound per outer
        // row, a shape lowering never produces — leave it for the
        // interpreter.
        if inner.emit.is_some() {
            return None;
        }
        let outer_table = self.catalog.get(&ox.relation).ok()?.clone();
        let build = self.catalog.get(&iix.relation).ok()?.clone();
        let build_key = build.schema.field_id(ifield)?;
        let outer_filter = match &ox.field_filter {
            Some((field, value)) => {
                let fid = outer_table.schema.field_id(field)?;
                Some((fid, self.expr_prog(value)?))
            }
            None => None,
        };
        let partition = match &ox.partition {
            Some(p) => Some((self.expr_prog(&p.part)?, self.expr_prog(&p.parts)?)),
            None => None,
        };
        let outer_cursor = self.n_cursors;
        self.n_cursors += 1;
        self.cursors
            .push((outer.var.clone(), outer_table.clone(), outer_cursor));
        // Probe key: compiled with the outer cursor (but not the inner
        // one) in scope, exactly the scope the interpreter evaluates the
        // inner index set's filter in.
        let probe_key = self.expr_prog(ikey);
        let build_cursor = self.n_cursors;
        self.n_cursors += 1;
        self.cursors
            .push((inner.var.clone(), build.clone(), build_cursor));
        // Deeper chain levels: while the current body is exactly one more
        // filtered forelem (no distinct/partition/emit), absorb it as a
        // further build side. Each level's probe key compiles with all
        // enclosing cursors in scope, so star keys (outer cursor) and
        // snowflake keys (an earlier build cursor) both resolve. Anything
        // else stops the descent; an unsupported nested data loop then
        // fails in `stmts` below and the whole nest falls back to the
        // interpreter, exactly as before.
        let mut deeper: Vec<JoinLevel> = Vec::new();
        let mut cur = inner;
        loop {
            let [Stmt::Loop(next)] = cur.body.as_slice() else {
                break;
            };
            let Domain::IndexSet(nix) = &next.domain else {
                break;
            };
            let Some((nfield, nkey)) = nix.field_filter.as_ref() else {
                break;
            };
            if nix.distinct.is_some() || nix.partition.is_some() || next.emit.is_some() {
                break;
            }
            let Some(tbl) = self.catalog.get(&nix.relation).ok().cloned() else {
                break;
            };
            let Some(level_key) = tbl.schema.field_id(nfield) else {
                break;
            };
            let Some(level_probe) = self.expr_prog(nkey) else {
                break;
            };
            let cursor = self.n_cursors;
            self.n_cursors += 1;
            self.cursors.push((next.var.clone(), tbl.clone(), cursor));
            deeper.push(JoinLevel {
                build: tbl,
                cursor,
                build_key: level_key,
                probe_key: level_probe,
            });
            cur = next;
        }
        self.no_fresh_binds += 1;
        let body = self.stmts(&cur.body);
        self.no_fresh_binds -= 1;
        for _ in 0..2 + deeper.len() {
            self.cursors.pop();
        }
        let probe_key = probe_key?;
        let body = body?;
        let probe_field = match probe_key.ops.as_slice() {
            [Op::LoadField { cursor, field, .. }] if *cursor == outer_cursor => Some(*field),
            _ => None,
        };
        // Fused aggregation only for the two-table shape, without an
        // outer filter (mirroring `detect_fast`) and with a direct probe
        // column.
        let fast = if deeper.is_empty() && ox.field_filter.is_none() && probe_field.is_some() {
            self.detect_join_fast(outer, inner, &outer_table, &build)
        } else {
            None
        };
        Some(CStmt::Join(JoinLoop {
            outer: outer_table,
            outer_cursor,
            outer_filter,
            partition,
            build,
            build_cursor,
            build_key,
            probe_key,
            probe_field,
            deeper,
            body,
            fast,
            emit: outer.emit.as_ref().map(EmitSpec::from_ir),
        }))
    }

    /// Recognize `forelem i { forelem j { a[key]++ / a[key] += v } }`
    /// join bodies the fused per-match kernels can execute; `key` and `v`
    /// may live on either side. Zero-init guards mirror `detect_fast`.
    fn detect_join_fast(
        &self,
        outer: &Loop,
        inner: &Loop,
        outer_table: &Arc<Table>,
        build: &Arc<Table>,
    ) -> Option<JoinFastAgg> {
        use crate::storage::Column;
        let [Stmt::Accum {
            array,
            indices,
            op: AccumOp::Add,
            value,
        }] = inner.body.as_slice()
        else {
            return None;
        };
        let [Expr::Field { var, field }] = indices.as_slice() else {
            return None;
        };
        // Innermost binding wins, mirroring cursor resolution in `expr`
        // (and the interpreter's env stack): when both loops bind the
        // same name, it refers to the inner (build) cursor.
        let side_of = |v: &str| -> Option<JoinSide> {
            if v == inner.var {
                Some(JoinSide::Build)
            } else if v == outer.var {
                Some(JoinSide::Outer)
            } else {
                None
            }
        };
        let key_side = side_of(var)?;
        let key_table = match key_side {
            JoinSide::Outer => outer_table,
            JoinSide::Build => build,
        };
        let key_field = key_table.schema.field_id(field)?;
        if !matches!(
            key_table.column(key_field),
            Column::Ints(_) | Column::DictStrs { .. } | Column::Strs(_)
        ) {
            return None;
        }
        let slot = self.slots.array_slot(array)?;
        let init = &self.program.arrays[array].init;
        match value {
            Expr::Const(Value::Int(1)) if matches!(init, Value::Int(0)) => {
                Some(JoinFastAgg::Count {
                    array: slot,
                    key_side,
                    key_field,
                })
            }
            Expr::Field {
                var: vvar,
                field: vfield,
            } => {
                let val_side = side_of(vvar)?;
                let val_table = match val_side {
                    JoinSide::Outer => outer_table,
                    JoinSide::Build => build,
                };
                let val_field = val_table.schema.field_id(vfield)?;
                let zero_init = match (val_table.column(val_field), init) {
                    // i64 accumulation requires a strict Int(0) start.
                    (Column::Ints(_), Value::Int(0)) => true,
                    // f64 accumulation: Int(0) and +0.0 fold identically.
                    (Column::Floats(_), Value::Int(0)) => true,
                    (Column::Floats(_), Value::Float(f)) => f.to_bits() == 0f64.to_bits(),
                    _ => false,
                };
                if zero_init {
                    Some(JoinFastAgg::Sum {
                        array: slot,
                        key_side,
                        key_field,
                        val_side,
                        val_field,
                    })
                } else {
                    None
                }
            }
            _ => None,
        }
    }

    /// Recognize `forelem i { a[i.key] (+)= v }` bodies that the batch
    /// kernels can execute. Zero-init guards keep the accumulation value
    /// types (and float fold results) bit-identical to the interpreter.
    fn detect_fast(&self, l: &Loop, table: &Arc<Table>) -> Option<FastAgg> {
        use crate::storage::Column;
        let [Stmt::Accum {
            array,
            indices,
            op: AccumOp::Add,
            value,
        }] = l.body.as_slice()
        else {
            return None;
        };
        let [Expr::Field { var, field }] = indices.as_slice() else {
            return None;
        };
        if var != &l.var {
            return None;
        }
        let key_field = table.schema.field_id(field)?;
        if !matches!(
            table.column(key_field),
            Column::Ints(_) | Column::DictStrs { .. } | Column::Strs(_) | Column::CompressedInts(_)
        ) {
            return None;
        }
        let slot = self.slots.array_slot(array)?;
        let init = &self.program.arrays[array].init;
        match value {
            Expr::Const(Value::Int(1)) if matches!(init, Value::Int(0)) => Some(FastAgg::Count {
                array: slot,
                key_field,
            }),
            Expr::Field {
                var: vvar,
                field: vfield,
            } if vvar == &l.var => {
                let val_field = table.schema.field_id(vfield)?;
                let zero_init = match (table.column(val_field), init) {
                    // i64 accumulation requires a strict Int(0) start.
                    (Column::Ints(_), Value::Int(0)) => true,
                    // f64 accumulation: Int(0) and +0.0 fold identically.
                    (Column::Floats(_), Value::Int(0)) => true,
                    (Column::Floats(_), Value::Float(f)) => f.to_bits() == 0f64.to_bits(),
                    _ => false,
                };
                if zero_init {
                    Some(FastAgg::Sum {
                        array: slot,
                        key_field,
                        val_field,
                    })
                } else {
                    None
                }
            }
            _ => None,
        }
    }

    /// Compile one expression into a fresh register program.
    fn expr_prog(&mut self, e: &Expr) -> Option<ExprProg> {
        let mut ops = Vec::new();
        let mut regs = 0usize;
        let out = self.expr(e, &mut ops, &mut regs)?;
        self.n_regs = self.n_regs.max(regs);
        Some(ExprProg {
            ops,
            n_regs: regs,
            out,
        })
    }

    fn expr(&mut self, e: &Expr, ops: &mut Vec<Op>, regs: &mut usize) -> Option<usize> {
        let mut alloc = |regs: &mut usize| {
            let r = *regs;
            *regs += 1;
            r
        };
        match e {
            Expr::Const(v) => {
                let dst = alloc(regs);
                ops.push(Op::Const {
                    dst,
                    v: v.clone(),
                });
                Some(dst)
            }
            Expr::Var(name) => {
                // Interpreter resolution order: env (innermost first),
                // then params. Params compile to a late-bound load so one
                // compiled program serves every prepared-statement
                // binding (`exec::vector` substitutes the bound values at
                // run time).
                if let Some((_, slot)) = self.scopes.iter().rev().find(|(n, _)| n == name) {
                    let dst = alloc(regs);
                    ops.push(Op::LoadScalar { dst, slot: *slot });
                    return Some(dst);
                }
                if let Some(param) = self.program.params.keys().position(|k| k == name) {
                    let dst = alloc(regs);
                    ops.push(Op::LoadParam { dst, param });
                    return Some(dst);
                }
                None
            }
            Expr::Field { var, field } => {
                let (_, table, cursor) =
                    self.cursors.iter().rev().find(|(n, _, _)| n == var)?;
                let fid = table.schema.field_id(field)?;
                let cursor = *cursor;
                let dst = alloc(regs);
                ops.push(Op::LoadField {
                    dst,
                    cursor,
                    field: fid,
                });
                Some(dst)
            }
            Expr::ArrayRef { array, indices } => {
                let slot = self.slots.array_slot(array)?;
                let idx = indices
                    .iter()
                    .map(|i| self.expr(i, ops, regs))
                    .collect::<Option<Vec<_>>>()?;
                let dst = alloc(regs);
                ops.push(Op::ReadArray {
                    dst,
                    array: slot,
                    idx,
                });
                Some(dst)
            }
            Expr::Binary { op, lhs, rhs } => {
                if *op == BinOp::And || *op == BinOp::Or {
                    let l = self.expr(lhs, ops, regs)?;
                    let dst = alloc(regs);
                    ops.push(Op::Truthy { dst, src: l });
                    let jump_at = ops.len();
                    // Placeholder; patched after the rhs block is emitted.
                    ops.push(if *op == BinOp::And {
                        Op::SkipIfFalse { src: dst, n: 0 }
                    } else {
                        Op::SkipIfTrue { src: dst, n: 0 }
                    });
                    let r = self.expr(rhs, ops, regs)?;
                    ops.push(Op::Truthy { dst, src: r });
                    let n = ops.len() - jump_at - 1;
                    match &mut ops[jump_at] {
                        Op::SkipIfFalse { n: slot, .. } | Op::SkipIfTrue { n: slot, .. } => {
                            *slot = n
                        }
                        _ => unreachable!(),
                    }
                    return Some(dst);
                }
                let l = self.expr(lhs, ops, regs)?;
                let r = self.expr(rhs, ops, regs)?;
                let dst = alloc(regs);
                ops.push(Op::Binary {
                    dst,
                    op: *op,
                    lhs: l,
                    rhs: r,
                });
                Some(dst)
            }
            Expr::Unary { op, expr } => {
                let src = self.expr(expr, ops, regs)?;
                let dst = alloc(regs);
                ops.push(Op::Unary {
                    dst,
                    op: *op,
                    src,
                });
                Some(dst)
            }
            Expr::SumOverParts { var, parts, body } => {
                let parts = self.expr(parts, ops, regs)?;
                let slot = self.scalar_inits.len();
                self.scalar_inits.push(Value::Null);
                self.scopes.push((var.clone(), slot));
                // The body shares this program's register numbering so one
                // scratch buffer serves the whole evaluation.
                let mut body_ops = Vec::new();
                let body_out = self.expr(body, &mut body_ops, regs);
                self.scopes.pop();
                let body_out = body_out?;
                let dst = alloc(regs);
                ops.push(Op::Sum {
                    dst,
                    slot,
                    parts,
                    body: Box::new(ExprProg {
                        ops: body_ops,
                        n_regs: *regs,
                        out: body_out,
                    }),
                });
                Some(dst)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArrayDecl, DataType, IndexSet, Multiset};
    use crate::sql::compile_sql;
    use crate::storage::StorageCatalog;

    fn catalog() -> StorageCatalog {
        let schema = Schema::new(vec![("url", DataType::Str), ("ms", DataType::Float)]);
        let mut m = Multiset::new(schema);
        for (u, ms) in [("/a", 1.0), ("/b", 2.0), ("/a", 3.0)] {
            m.push(vec![Value::str(u), Value::Float(ms)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        c
    }

    #[test]
    fn group_count_compiles_with_fast_agg() {
        let c = catalog();
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let cp = compile_program(&p, &c).expect("supported shape");
        assert_eq!(cp.body.len(), 2);
        let CStmt::Scan(acc) = &cp.body[0] else {
            panic!("expected scan loop");
        };
        assert!(matches!(acc.fast, Some(FastAgg::Count { .. })));
        let CStmt::Scan(emit) = &cp.body[1] else {
            panic!("expected scan loop");
        };
        assert!(emit.distinct.is_some());
        assert!(emit.fast.is_none());
    }

    #[test]
    fn group_sum_detects_fast_sum() {
        let c = catalog();
        let p = compile_sql(
            "SELECT url, SUM(ms) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let cp = compile_program(&p, &c).expect("supported shape");
        let CStmt::Scan(acc) = &cp.body[0] else {
            panic!("expected scan loop");
        };
        assert!(matches!(acc.fast, Some(FastAgg::Sum { .. })));
    }

    fn join_catalog() -> StorageCatalog {
        let mut c = StorageCatalog::new();
        let a = Multiset::with_rows(
            Schema::new(vec![("b_id", DataType::Int), ("g", DataType::Str)]),
            vec![
                vec![Value::Int(1), Value::str("x")],
                vec![Value::Int(2), Value::str("y")],
                vec![Value::Int(1), Value::str("x")],
            ],
        );
        let b = Multiset::with_rows(
            Schema::new(vec![("id", DataType::Int), ("v", DataType::Float)]),
            vec![
                vec![Value::Int(1), Value::Float(0.5)],
                vec![Value::Int(2), Value::Float(1.5)],
            ],
        );
        c.insert_multiset("A", &a).unwrap();
        c.insert_multiset("B", &b).unwrap();
        c
    }

    #[test]
    fn figure1_join_compiles_to_hash_join() {
        let c = join_catalog();
        let p = compile_sql(
            "SELECT A.b_id FROM A JOIN B ON A.b_id = B.id",
            &c.schemas(),
        )
        .unwrap();
        let cp = compile_program(&p, &c).expect("join shape is supported");
        let [CStmt::Join(j)] = cp.body.as_slice() else {
            panic!("expected a compiled join, got {:?}", cp.body);
        };
        assert_eq!(j.build_key, 0);
        assert_eq!(j.probe_field, Some(0));
        assert!(j.outer_filter.is_none());
        assert!(j.fast.is_none()); // plain projection body
    }

    #[test]
    fn join_group_by_count_detects_fast_agg() {
        let c = join_catalog();
        let p = compile_sql(
            "SELECT g, COUNT(g) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
            &c.schemas(),
        )
        .unwrap();
        let cp = compile_program(&p, &c).expect("join aggregate is supported");
        let CStmt::Join(j) = &cp.body[0] else {
            panic!("expected a compiled join, got {:?}", cp.body);
        };
        assert!(matches!(
            j.fast,
            Some(JoinFastAgg::Count {
                key_side: JoinSide::Outer,
                ..
            })
        ));
        // Emit loop over distinct group keys follows.
        assert!(matches!(&cp.body[1], CStmt::Scan(s) if s.distinct.is_some()));
    }

    #[test]
    fn join_group_by_sum_detects_cross_side_fast_agg() {
        let c = join_catalog();
        let p = compile_sql(
            "SELECT g, SUM(v) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
            &c.schemas(),
        )
        .unwrap();
        let cp = compile_program(&p, &c).expect("join aggregate is supported");
        let CStmt::Join(j) = &cp.body[0] else {
            panic!("expected a compiled join");
        };
        assert!(matches!(
            j.fast,
            Some(JoinFastAgg::Sum {
                key_side: JoinSide::Outer,
                val_side: JoinSide::Build,
                ..
            })
        ));
    }

    #[test]
    fn optimizer_swapped_nest_builds_on_the_small_side() {
        // `dim` written first (small), `fact` second (big): as lowered,
        // the JoinLoop hashes `fact`; after `opt::optimize` swaps the
        // nest, the build side must be `dim` and results must not change.
        let mut c = StorageCatalog::new();
        let mut dim = Multiset::new(Schema::new(vec![("id", DataType::Int)]));
        for i in 0..8i64 {
            dim.push(vec![Value::Int(i)]);
        }
        let mut fact = Multiset::new(Schema::new(vec![("a_id", DataType::Int)]));
        for i in 0..64i64 {
            fact.push(vec![Value::Int(i % 11)]);
        }
        c.insert_multiset("dim", &dim).unwrap();
        c.insert_multiset("fact", &fact).unwrap();
        let p0 = compile_sql(
            "SELECT dim.id FROM dim JOIN fact ON dim.id = fact.a_id",
            &c.schemas(),
        )
        .unwrap();
        let unopt = compile_program(&p0, &c).expect("join shape");
        let [CStmt::Join(j0)] = unopt.body.as_slice() else {
            panic!("expected a compiled join");
        };
        assert_eq!(j0.build.len(), 64, "as lowered: builds on fact");

        let mut p1 = p0.clone();
        crate::opt::optimize(&mut p1, &c).unwrap();
        let cp = compile_program(&p1, &c).expect("swapped nest still compiles");
        let [CStmt::Join(j)] = cp.body.as_slice() else {
            panic!("expected a compiled join after the swap");
        };
        assert_eq!(j.build.len(), 8, "optimizer must hash the small side");
        assert_eq!(j.outer.len(), 64);

        let a = crate::exec::run(&p0, &c).unwrap();
        let b = crate::exec::run(&p1, &c).unwrap();
        assert!(a.result().unwrap().bag_eq(b.result().unwrap()));
    }

    #[test]
    fn three_deep_forelem_nests_compile_as_a_chain() {
        // A filtered forelem inside the join body is one more chain
        // level: the nest compiles with a `deeper` build side whose probe
        // key references the level-1 build cursor (snowflake shape).
        let c = join_catalog();
        let mut p = Program::new("deep")
            .with_relation("A", c.schemas()["A"].clone())
            .with_relation("B", c.schemas()["B"].clone())
            .with_result("R", Schema::new(vec![("g", DataType::Str)]));
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("A"),
            vec![Stmt::Loop(Loop::forelem(
                "j",
                IndexSet::filtered("B", "id", Expr::field("i", "b_id")),
                vec![Stmt::Loop(Loop::forelem(
                    "k",
                    IndexSet::filtered("A", "b_id", Expr::field("j", "id")),
                    vec![Stmt::result_union("R", vec![Expr::field("k", "g")])],
                ))],
            ))],
        ))];
        let cp = compile_program(&p, &c).expect("3-deep chain is supported");
        let [CStmt::Join(j)] = cp.body.as_slice() else {
            panic!("expected a compiled join chain, got {:?}", cp.body);
        };
        assert_eq!(j.deeper.len(), 1);
        assert_eq!(j.deeper[0].cursor, 2);
        assert_eq!(j.deeper[0].build.len(), 3, "level 2 hashes A");
        assert_eq!(j.deeper[0].build_key, 0, "keyed on b_id");
        assert!(j.fast.is_none(), "fused kernels stay two-table only");
        assert_eq!(cp.n_cursors, 3);
    }

    #[test]
    fn chain_with_inner_distinct_falls_back() {
        // A distinct iteration below the join nest is outside the chain
        // shape; the whole program keeps the interpreter.
        let c = join_catalog();
        let mut p = Program::new("deep_distinct")
            .with_relation("A", c.schemas()["A"].clone())
            .with_relation("B", c.schemas()["B"].clone())
            .with_result("R", Schema::new(vec![("g", DataType::Str)]));
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("A"),
            vec![Stmt::Loop(Loop::forelem(
                "j",
                IndexSet::filtered("B", "id", Expr::field("i", "b_id")),
                vec![Stmt::Loop(Loop::forelem(
                    "k",
                    IndexSet::distinct_of("A", "g"),
                    vec![Stmt::result_union("R", vec![Expr::field("k", "g")])],
                ))],
            ))],
        ))];
        assert!(compile_program(&p, &c).is_none());
    }

    #[test]
    fn unbound_names_fall_back() {
        let c = catalog();
        let mut p = Program::new("bad")
            .with_relation("access", c.schemas()["access"].clone())
            .with_result("R", Schema::new(vec![("x", DataType::Int)]));
        p.body = vec![Stmt::result_union("R", vec![Expr::var("nope")])];
        assert!(compile_program(&p, &c).is_none());
    }

    #[test]
    fn fresh_assign_inside_if_falls_back() {
        // A first-time assignment inside a conditionally-executed branch
        // must not be pre-bound to a slot: the interpreter only creates
        // the binding when the branch runs.
        let c = catalog();
        let mut p = Program::new("cond")
            .with_relation("access", c.schemas()["access"].clone())
            .with_scalar("flag", Value::Bool(false));
        p.body = vec![Stmt::If {
            cond: Expr::var("flag"),
            then: vec![Stmt::assign("x", Expr::int(1))],
            els: vec![],
        }];
        assert!(compile_program(&p, &c).is_none());
        // Assigning to a *declared* scalar inside a branch stays fine.
        let mut p2 = Program::new("cond2")
            .with_relation("access", c.schemas()["access"].clone())
            .with_scalar("flag", Value::Bool(false))
            .with_scalar("x", Value::Int(0));
        p2.body = vec![Stmt::If {
            cond: Expr::var("flag"),
            then: vec![Stmt::assign("x", Expr::int(1))],
            els: vec![],
        }];
        assert!(compile_program(&p2, &c).is_some());
    }

    #[test]
    fn indexed_strategy_probe_inside_range_loop_falls_back() {
        // A hash-strategy filtered scan repeated by a range loop keeps the
        // interpreter's cached index probes instead of K full scans.
        use crate::ir::Strategy;
        let c = catalog();
        let mut p = Program::new("probe")
            .with_relation("access", c.schemas()["access"].clone())
            .with_result("R", Schema::new(vec![("url", DataType::Str)]));
        p.body = vec![Stmt::Loop(Loop::for_range(
            "k",
            Expr::int(1),
            Expr::int(3),
            vec![Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::filtered("access", "url", Expr::str("/a"))
                    .with_strategy(Strategy::Hash),
                vec![Stmt::result_union("R", vec![Expr::field("i", "url")])],
            ))],
        ))];
        assert!(compile_program(&p, &c).is_none());
        // The same scan at top level (runs once) stays vectorized.
        let mut p2 = Program::new("probe2")
            .with_relation("access", c.schemas()["access"].clone())
            .with_result("R", Schema::new(vec![("url", DataType::Str)]));
        p2.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::filtered("access", "url", Expr::str("/a")).with_strategy(Strategy::Hash),
            vec![Stmt::result_union("R", vec![Expr::field("i", "url")])],
        ))];
        assert!(compile_program(&p2, &c).is_some());
    }

    #[test]
    fn params_compile_to_late_bound_loads() {
        // Params must stay runtime loads — not folded constants — so one
        // compiled program serves every prepared-statement binding. The
        // compile-time value survives as the default in `param_inits`.
        let c = catalog();
        let mut p = Program::new("p")
            .with_relation("access", c.schemas()["access"].clone())
            .with_param("N", Value::Int(4))
            .with_scalar("x", Value::Int(0));
        p.body = vec![Stmt::assign("x", Expr::var("N"))];
        let cp = compile_program(&p, &c).unwrap();
        assert_eq!(cp.param_names, vec!["N".to_string()]);
        assert_eq!(cp.param_inits, vec![Value::Int(4)]);
        let CStmt::Assign { value, .. } = &cp.body[0] else {
            panic!("expected assign");
        };
        assert!(matches!(
            value.ops.as_slice(),
            [Op::LoadParam { param: 0, .. }]
        ));
    }

    #[test]
    fn partitioned_forall_compiles() {
        let c = catalog();
        let mut p = Program::new("part")
            .with_relation("access", c.schemas()["access"].clone())
            .with_array("count", ArrayDecl::counter())
            .with_param("N", Value::Int(2))
            .with_result(
                "R",
                Schema::new(vec![("url", DataType::Str), ("n", DataType::Int)]),
            );
        p.body = vec![
            Stmt::Loop(Loop::forall_range(
                "k",
                Expr::int(1),
                Expr::var("N"),
                vec![Stmt::Loop(Loop::forelem(
                    "i",
                    IndexSet::all("access").with_partition(Expr::var("k"), Expr::var("N")),
                    vec![Stmt::increment("count", vec![Expr::field("i", "url")])],
                ))],
            )),
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::distinct_of("access", "url"),
                vec![Stmt::result_union(
                    "R",
                    vec![
                        Expr::field("i", "url"),
                        Expr::array("count", vec![Expr::field("i", "url")]),
                    ],
                )],
            )),
        ];
        let cp = compile_program(&p, &c).expect("supported shape");
        let CStmt::Range { kind, body, .. } = &cp.body[0] else {
            panic!("expected range loop");
        };
        assert_eq!(*kind, LoopKind::Forall);
        assert!(matches!(body.as_slice(), [CStmt::Scan(_)]));
    }

    #[test]
    fn scan_parallel_safety_classifies_bodies() {
        let c = catalog();
        // Accumulate-only body: eligible for the morsel driver.
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let cp = compile_program(&p, &c).unwrap();
        let CStmt::Scan(acc) = &cp.body[0] else {
            panic!("expected scan loop");
        };
        assert!(scan_parallel_safe(acc));
        // The distinct emit loop reads accumulator state: ineligible.
        let CStmt::Scan(emit) = &cp.body[1] else {
            panic!("expected scan loop");
        };
        assert!(!scan_parallel_safe(emit));

        // Scalar assignments keep a scan on the sequential driver.
        let mut p2 = Program::new("assign")
            .with_relation("access", c.schemas()["access"].clone())
            .with_scalar("x", Value::Float(0.0));
        p2.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("access"),
            vec![Stmt::assign("x", Expr::field("i", "ms"))],
        ))];
        let cp2 = compile_program(&p2, &c).unwrap();
        let CStmt::Scan(s) = &cp2.body[0] else {
            panic!("expected scan loop");
        };
        assert!(!scan_parallel_safe(s));

        // Prints keep a scan on the sequential driver.
        let mut p3 = Program::new("print")
            .with_relation("access", c.schemas()["access"].clone());
        p3.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("access"),
            vec![Stmt::Print {
                format: "{}".into(),
                args: vec![Expr::field("i", "url")],
            }],
        ))];
        let cp3 = compile_program(&p3, &c).unwrap();
        let CStmt::Scan(s) = &cp3.body[0] else {
            panic!("expected scan loop");
        };
        assert!(!scan_parallel_safe(s));
    }

    #[test]
    fn order_by_limit_compiles_to_an_emit_spec() {
        let c = catalog();
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url ORDER BY count DESC LIMIT 5",
            &c.schemas(),
        )
        .unwrap();
        let cp = compile_program(&p, &c).expect("topk group-by is supported");
        let CStmt::Scan(emit) = &cp.body[1] else {
            panic!("expected the emit scan");
        };
        let spec = emit.emit.as_ref().expect("emit spec attached");
        assert_eq!(spec.key, Some(1));
        assert!(spec.descending);
        assert_eq!(spec.limit, Some(5));
        // Undecided bounded emissions default to the heap kernel.
        assert!(spec.heap);
        // The emission contract keeps the loop off the plain morsel scan
        // path (it has its own top-k fan-out)...
        assert!(!scan_parallel_safe(emit));
        // ...and the Result-only body is eligible for that fan-out.
        assert!(emit_parallel_safe(emit));

        // An optimizer-decided Sort strategy turns the heap off.
        let mut sorted = p.clone();
        let Stmt::Loop(l) = &mut sorted.body[1] else {
            panic!("expected loop");
        };
        l.emit.as_mut().unwrap().strategy = crate::ir::TopKStrategy::Sort;
        let cp = compile_program(&sorted, &c).unwrap();
        let CStmt::Scan(emit) = &cp.body[1] else {
            panic!("expected the emit scan");
        };
        assert!(!emit.emit.as_ref().unwrap().heap);
        assert!(!emit_parallel_safe(emit));
    }

    #[test]
    fn ordered_join_nest_carries_the_emit_spec() {
        let c = join_catalog();
        let p = compile_sql(
            "SELECT A.b_id, B.v FROM A JOIN B ON A.b_id = B.id ORDER BY v DESC LIMIT 3",
            &c.schemas(),
        )
        .unwrap();
        let cp = compile_program(&p, &c).expect("ordered join is supported");
        let [CStmt::Join(j)] = cp.body.as_slice() else {
            panic!("expected a compiled join");
        };
        let spec = j.emit.as_ref().expect("emit spec on the nest");
        assert_eq!(spec.key, Some(1));
        assert_eq!(spec.limit, Some(3));
        // Emission order pins the probe sequence: no morsel fan-out.
        assert!(!join_parallel_safe(j));
    }

    #[test]
    fn sum_over_parts_compiles() {
        let c = catalog();
        let mut p = Program::new("sum")
            .with_relation("access", c.schemas()["access"].clone())
            .with_array("count", ArrayDecl::counter())
            .with_param("N", Value::Int(3))
            .with_scalar("total", Value::Int(0));
        p.body = vec![Stmt::assign(
            "total",
            Expr::SumOverParts {
                var: "k".into(),
                parts: Box::new(Expr::var("N")),
                body: Box::new(Expr::array("count", vec![Expr::var("k")])),
            },
        )];
        let cp = compile_program(&p, &c).expect("supported shape");
        let CStmt::Assign { value, .. } = &cp.body[0] else {
            panic!("expected assign");
        };
        assert!(value.ops.iter().any(|o| matches!(o, Op::Sum { .. })));
    }
}
