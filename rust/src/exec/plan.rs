//! Compiled plans: the analogue of the paper's code-generation stage.
//!
//! After the transformation pipeline has run, the compiler recognizes
//! aggregate idioms in the IR and executes them with specialized native
//! loops over typed columns instead of the generic interpreter — exactly
//! the paper's "efficient code is generated to execute these loops"
//! (§III-B). For dictionary-encoded (integer-keyed) data the hot loop can
//! additionally be dispatched to the AOT-compiled XLA kernels (L1/L2),
//! which is what the Figure-2 "integer keyed" variants measure.


use crate::util::FxHashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::ir::{
    AccumOp, Domain, Expr, Multiset, Program, Stmt, Value,
};
use crate::storage::{Column, StorageCatalog, Table};

use super::local::{self, Output};

/// Recognized whole-program idioms.
#[derive(Debug, Clone, PartialEq)]
pub enum Idiom {
    /// `forelem i∈pT { c[i.key]++ }; forelem i∈pT.distinct(key) { R ∪= (i.key, c[i.key]) }`
    GroupCount {
        table: String,
        key_field: String,
        result: String,
    },
    /// Same shape with `s[i.key] += i.val`.
    GroupSum {
        table: String,
        key_field: String,
        val_field: String,
        result: String,
    },
}

/// Hook into the XLA kernel runtime (implemented by `runtime::Kernels`).
/// Counts/sums are f32 on the device; chunking keeps them exact.
pub trait KernelExec: Sync {
    /// Histogram of `keys` (pad = -1 drops) over `[0, num_keys)`.
    fn group_count(&self, keys: &[i64], num_keys: usize) -> Result<Vec<i64>>;
    /// Per-key sums of `vals`.
    fn group_sum(&self, keys: &[i64], vals: &[f64], num_keys: usize) -> Result<Vec<f64>>;
}

/// True when any loop in the program carries an ordered/bounded emission
/// contract (`ORDER BY`/`LIMIT`). Such programs skip the idiom tier: the
/// plain group-by kernels emit unordered, while the vectorized tier runs
/// the emission as its fused `vec.topk` bounded-heap kernel. (The
/// distributed path still uses [`recognize`] for shape matching and
/// applies the contract to the merged result — see
/// `Engine::sql_distributed`.)
pub fn has_emit_bound(p: &Program) -> bool {
    p.emit_bound().is_some()
}

/// Try to recognize the program as one of the compiled idioms. Emission
/// contracts are ignored here — shape only; dispatchers that cannot
/// honour the contract must check [`has_emit_bound`].
pub fn recognize(p: &Program) -> Option<Idiom> {
    let loops: Vec<&crate::ir::Loop> = p
        .body
        .iter()
        .filter_map(|s| match s {
            Stmt::Loop(l) => Some(l),
            _ => None,
        })
        .collect();
    if loops.len() != 2 || p.body.len() != 2 {
        return None;
    }
    let (acc, emit) = (loops[0], loops[1]);

    // Accumulation loop: plain full iteration of a table.
    let Domain::IndexSet(aix) = &acc.domain else {
        return None;
    };
    if aix.field_filter.is_some() || aix.distinct.is_some() || aix.partition.is_some() {
        return None;
    }
    if acc.body.len() != 1 {
        return None;
    }
    let Stmt::Accum {
        array,
        indices,
        op: AccumOp::Add,
        value,
    } = &acc.body[0]
    else {
        return None;
    };
    let [Expr::Field { var: iv, field: key_field }] = indices.as_slice() else {
        return None;
    };
    if iv != &acc.var {
        return None;
    }

    // Emit loop: distinct iteration over the same table+field, emitting
    // (key, array[key]).
    let Domain::IndexSet(eix) = &emit.domain else {
        return None;
    };
    if eix.relation != aix.relation || eix.field_filter.is_some() || eix.partition.is_some() {
        return None;
    }
    if eix.distinct.as_deref() != Some(key_field.as_str()) {
        return None;
    }
    if emit.body.len() != 1 {
        return None;
    }
    let Stmt::ResultUnion { result, tuple } = &emit.body[0] else {
        return None;
    };
    let [Expr::Field { var: ev1, field: ef1 }, Expr::ArrayRef { array: ea, indices: eidx }] =
        tuple.as_slice()
    else {
        return None;
    };
    if ev1 != &emit.var || ef1 != key_field || ea != array {
        return None;
    }
    let [Expr::Field { var: ev2, field: ef2 }] = eidx.as_slice() else {
        return None;
    };
    if ev2 != &emit.var || ef2 != key_field {
        return None;
    }

    match value {
        Expr::Const(Value::Int(1)) => Some(Idiom::GroupCount {
            table: aix.relation.clone(),
            key_field: key_field.clone(),
            result: result.clone(),
        }),
        Expr::Field { var, field } if var == &acc.var => Some(Idiom::GroupSum {
            table: aix.relation.clone(),
            key_field: key_field.clone(),
            val_field: field.clone(),
            result: result.clone(),
        }),
        _ => None,
    }
}

/// Execute a program through the tier dispatch: a recognized whole-program
/// idiom runs on the native/XLA kernels; otherwise the vectorized batch
/// executor handles the program if its shape is supported; the reference
/// interpreter is the final fallback (and the semantic oracle for both).
///
/// This dispatch is sequential; `exec::run_parallel` (and its
/// policy-selecting variant) is the shared-memory counterpart that runs
/// the same compiled form morsel-driven across a worker pool.
pub fn run_compiled(
    p: &Program,
    catalog: &StorageCatalog,
    kernels: Option<&dyn KernelExec>,
) -> Result<Output> {
    let mut out = match recognize(p) {
        Some(idiom) if !has_emit_bound(p) => run_idiom(&idiom, p, catalog, kernels)?,
        _ => match super::vector::try_run(p, catalog)? {
            Some(out) => out,
            None => local::run(p, catalog)?,
        },
    };
    // Surface the optimizer's decisions alongside the tier tags so tests
    // and dashboards see *why* this plan shape executed.
    out.stats.note_opt_tags(&p.opt_tags);
    Ok(out)
}

fn run_idiom(
    idiom: &Idiom,
    p: &Program,
    catalog: &StorageCatalog,
    kernels: Option<&dyn KernelExec>,
) -> Result<Output> {
    let mut out = Output::default();
    match idiom {
        Idiom::GroupCount {
            table,
            key_field,
            result,
        } => {
            let t = catalog.get(table)?;
            let fid = t.schema.require_field(key_field)?;
            let schema = p.results[result].clone();
            let mut m = Multiset::new(schema);
            let mut kernel_calls = 0;
            match group_count_column(t, fid, kernels, &mut kernel_calls)? {
                GroupedInts::Dense { counts, decode } => {
                    for (k, &n) in counts.iter().enumerate() {
                        if n != 0 {
                            m.push(vec![decode(t, k), Value::Int(n)]);
                        }
                    }
                }
                GroupedInts::Assoc(map) => {
                    for (v, n) in map {
                        m.push(vec![v, Value::Int(n)]);
                    }
                }
            }
            out.stats.kernel_calls = kernel_calls;
            out.stats.rows_visited = t.len() as u64;
            out.stats.idioms.push("group_count".into());
            out.results.insert(result.clone(), m);
        }
        Idiom::GroupSum {
            table,
            key_field,
            val_field,
            result,
        } => {
            let t = catalog.get(table)?;
            let kf = t.schema.require_field(key_field)?;
            let vf = t.schema.require_field(val_field)?;
            let schema = p.results[result].clone();
            let float_out = matches!(schema.dtype(1), crate::ir::DataType::Float);
            let mut m = Multiset::new(schema);
            let mut kernel_calls = 0;
            match group_sum_column(t, kf, vf, kernels, &mut kernel_calls)? {
                GroupedFloats::Dense { sums, seen, decode } => {
                    for (k, (&s, &was_seen)) in sums.iter().zip(&seen).enumerate() {
                        if was_seen {
                            m.push(vec![decode(t, k), num(s, float_out)]);
                        }
                    }
                }
                GroupedFloats::Assoc(map) => {
                    for (v, s) in map {
                        m.push(vec![v, num(s, float_out)]);
                    }
                }
            }
            out.stats.kernel_calls = kernel_calls;
            out.stats.rows_visited = t.len() as u64;
            out.stats.idioms.push("group_sum".into());
            out.results.insert(result.clone(), m);
        }
    }
    Ok(out)
}

fn num(x: f64, float_out: bool) -> Value {
    if float_out {
        Value::Float(x)
    } else {
        Value::Int(x as i64)
    }
}

type Decode = fn(&Arc<Table>, usize) -> Value;

pub enum GroupedInts {
    Dense { counts: Vec<i64>, decode: Decode },
    Assoc(Vec<(Value, i64)>),
}

pub enum GroupedFloats {
    Dense {
        sums: Vec<f64>,
        seen: Vec<bool>,
        decode: Decode,
    },
    Assoc(Vec<(Value, f64)>),
}

fn decode_dict(t: &Arc<Table>, k: usize) -> Value {
    // Used only when the keyed column is dictionary-encoded at field 0 of
    // the grouping — decode restores the original string.
    for c in &t.columns {
        if let Column::DictStrs { dict, .. } = c {
            if let Some(s) = dict.decode(k as u32) {
                return Value::Str(s.clone());
            }
        }
    }
    Value::Int(k as i64)
}

fn decode_int(_t: &Arc<Table>, k: usize) -> Value {
    Value::Int(k as i64)
}

/// Count occurrences per key over one column (the §IV URL-count hot loop),
/// picking the best available path:
/// * dictionary-encoded / dense small ints → dense native loop, optionally
///   offloaded to the XLA kernel runtime in chunks;
/// * plain strings / wide ints → associative map (first-seen order).
pub fn group_count_column(
    t: &Arc<Table>,
    field: usize,
    kernels: Option<&dyn KernelExec>,
    kernel_calls: &mut usize,
) -> Result<GroupedIntsPublic> {
    let col = t.column(field);
    match col {
        Column::DictStrs { keys, dict } => {
            let num_keys = dict.len();
            let counts = count_dense_u32(keys, num_keys, kernels, kernel_calls)?;
            Ok(GroupedInts::Dense {
                counts,
                decode: decode_dict,
            })
        }
        Column::Ints(vals) => {
            // Dense path only when the key range is compact.
            let max = vals.iter().copied().max().unwrap_or(0);
            let min = vals.iter().copied().min().unwrap_or(0);
            if min >= 0 && (max as usize) < vals.len().max(1024) * 4 {
                let num_keys = max as usize + 1;
                let counts = count_dense_i64(vals, num_keys, kernels, kernel_calls)?;
                Ok(GroupedInts::Dense {
                    counts,
                    decode: decode_int,
                })
            } else {
                Ok(GroupedInts::Assoc(count_assoc(t, field)))
            }
        }
        _ => Ok(GroupedInts::Assoc(count_assoc(t, field))),
    }
}

// The enum is private plumbing but the function above is public; alias so
// the signature stays expressible.
use GroupedInts as GroupedIntsPublic;

fn count_assoc(t: &Arc<Table>, field: usize) -> Vec<(Value, i64)> {
    let mut order: Vec<Value> = Vec::new();
    let mut map: FxHashMap<Value, i64> = FxHashMap::default();
    // Fast string path: hash Arc<str> contents once per row.
    if let Column::Strs(vals) = t.column(field) {
        let mut smap: FxHashMap<Arc<str>, i64> = FxHashMap::default();
        let mut sorder: Vec<Arc<str>> = Vec::new();
        for s in vals {
            match smap.get_mut(s) {
                Some(n) => *n += 1,
                None => {
                    smap.insert(s.clone(), 1);
                    sorder.push(s.clone());
                }
            }
        }
        return sorder
            .into_iter()
            .map(|s| {
                let n = smap[&s];
                (Value::Str(s), n)
            })
            .collect();
    }
    for row in 0..t.len() {
        let v = t.value(row, field);
        match map.get_mut(&v) {
            Some(n) => *n += 1,
            None => {
                map.insert(v.clone(), 1);
                order.push(v);
            }
        }
    }
    order
        .into_iter()
        .map(|v| {
            let n = map[&v];
            (v, n)
        })
        .collect()
}

/// Kernel chunk size: matches the largest AOT artifact (`count_scatter_65536x*`).
pub const KERNEL_CHUNK: usize = 65536;
/// Key-space width of the large AOT artifacts.
pub const KERNEL_KEYSPACE: usize = 131072;

fn count_dense_u32(
    keys: &[u32],
    num_keys: usize,
    kernels: Option<&dyn KernelExec>,
    kernel_calls: &mut usize,
) -> Result<Vec<i64>> {
    if let Some(k) = kernels {
        if num_keys <= KERNEL_KEYSPACE {
            let keys64: Vec<i64> = keys.iter().map(|&x| x as i64).collect();
            *kernel_calls += keys64.len().div_ceil(KERNEL_CHUNK);
            let mut counts = k.group_count(&keys64, num_keys)?;
            counts.truncate(num_keys);
            return Ok(counts);
        }
    }
    let mut counts = vec![0i64; num_keys];
    super::vector::count_batch_u32(keys, &mut counts);
    Ok(counts)
}

fn count_dense_i64(
    keys: &[i64],
    num_keys: usize,
    kernels: Option<&dyn KernelExec>,
    kernel_calls: &mut usize,
) -> Result<Vec<i64>> {
    if let Some(k) = kernels {
        if num_keys <= KERNEL_KEYSPACE {
            *kernel_calls += keys.len().div_ceil(KERNEL_CHUNK);
            let mut counts = k.group_count(keys, num_keys)?;
            counts.truncate(num_keys);
            return Ok(counts);
        }
    }
    let mut counts = vec![0i64; num_keys];
    super::vector::count_batch_i64(keys, &mut counts);
    Ok(counts)
}

/// Per-key sums over (key column, value column).
pub fn group_sum_column(
    t: &Arc<Table>,
    key_field: usize,
    val_field: usize,
    kernels: Option<&dyn KernelExec>,
    kernel_calls: &mut usize,
) -> Result<GroupedFloatsPublic> {
    let kcol = t.column(key_field);
    let vals: Vec<f64> = match t.column(val_field) {
        Column::Floats(v) => v.clone(),
        Column::Ints(v) => v.iter().map(|&x| x as f64).collect(),
        _ => {
            return Ok(GroupedFloats::Assoc(sum_assoc(t, key_field, val_field)));
        }
    };
    match kcol {
        Column::DictStrs { keys, dict } => {
            let num_keys = dict.len();
            let keys64: Vec<i64> = keys.iter().map(|&x| x as i64).collect();
            let (sums, seen) =
                sum_dense(&keys64, &vals, num_keys, kernels, kernel_calls)?;
            Ok(GroupedFloats::Dense {
                sums,
                seen,
                decode: decode_dict,
            })
        }
        Column::Ints(keys) => {
            let max = keys.iter().copied().max().unwrap_or(0);
            let min = keys.iter().copied().min().unwrap_or(0);
            if min >= 0 && (max as usize) < keys.len().max(1024) * 4 {
                let num_keys = max as usize + 1;
                let (sums, seen) = sum_dense(keys, &vals, num_keys, kernels, kernel_calls)?;
                Ok(GroupedFloats::Dense {
                    sums,
                    seen,
                    decode: decode_int,
                })
            } else {
                Ok(GroupedFloats::Assoc(sum_assoc(t, key_field, val_field)))
            }
        }
        _ => Ok(GroupedFloats::Assoc(sum_assoc(t, key_field, val_field))),
    }
}

use GroupedFloats as GroupedFloatsPublic;

fn sum_dense(
    keys: &[i64],
    vals: &[f64],
    num_keys: usize,
    kernels: Option<&dyn KernelExec>,
    kernel_calls: &mut usize,
) -> Result<(Vec<f64>, Vec<bool>)> {
    let mut seen = vec![false; num_keys];
    for &k in keys {
        seen[k as usize] = true;
    }
    if let Some(kr) = kernels {
        if num_keys <= KERNEL_KEYSPACE {
            *kernel_calls += keys.len().div_ceil(KERNEL_CHUNK);
            let mut sums = kr.group_sum(keys, vals, num_keys)?;
            sums.truncate(num_keys);
            return Ok((sums, seen));
        }
    }
    let mut sums = vec![0f64; num_keys];
    super::vector::sum_batch_i64(keys, vals, &mut sums);
    Ok((sums, seen))
}

fn sum_assoc(t: &Arc<Table>, key_field: usize, val_field: usize) -> Vec<(Value, f64)> {
    let mut order: Vec<Value> = Vec::new();
    let mut map: FxHashMap<Value, f64> = FxHashMap::default();
    for row in 0..t.len() {
        let k = t.value(row, key_field);
        let v = t.value(row, val_field).as_float().unwrap_or(0.0);
        match map.get_mut(&k) {
            Some(s) => *s += v,
            None => {
                map.insert(k.clone(), v);
                order.push(k);
            }
        }
    }
    order
        .into_iter()
        .map(|k| {
            let s = map[&k];
            (k, s)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Schema};
    use crate::sql::compile_sql;
    use crate::storage::StorageCatalog;

    fn catalog(dict_encode: bool) -> StorageCatalog {
        let schema = Schema::new(vec![("url", DataType::Str), ("ms", DataType::Float)]);
        let mut m = Multiset::new(schema);
        for (u, ms) in [
            ("/a", 1.0),
            ("/b", 2.0),
            ("/a", 3.0),
            ("/c", 4.0),
            ("/a", 5.0),
        ] {
            m.push(vec![Value::str(u), Value::Float(ms)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        if dict_encode {
            let mut t = (**c.get("access").unwrap()).clone();
            t.dict_encode_field(0).unwrap();
            c.replace("access", t);
        }
        c
    }

    #[test]
    fn recognizes_sql_lowered_group_count() {
        let c = catalog(false);
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        assert_eq!(
            recognize(&p),
            Some(Idiom::GroupCount {
                table: "access".into(),
                key_field: "url".into(),
                result: "R".into(),
            })
        );
    }

    #[test]
    fn recognizes_group_sum() {
        let c = catalog(false);
        let p = compile_sql(
            "SELECT url, SUM(ms) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        assert!(matches!(recognize(&p), Some(Idiom::GroupSum { .. })));
    }

    #[test]
    fn compiled_matches_interpreter_strings_and_dict() {
        for dict in [false, true] {
            let c = catalog(dict);
            let p = compile_sql(
                "SELECT url, COUNT(url) FROM access GROUP BY url",
                &c.schemas(),
            )
            .unwrap();
            let compiled = run_compiled(&p, &c, None).unwrap();
            let reference = local::run(&p, &c).unwrap();
            assert!(
                compiled
                    .result()
                    .unwrap()
                    .bag_eq(reference.result().unwrap()),
                "dict={dict}: {:?} vs {:?}",
                compiled.result().unwrap(),
                reference.result().unwrap()
            );
            assert!(compiled.stats.idioms.contains(&"group_count".to_string()));
        }
    }

    #[test]
    fn compiled_group_sum_matches_interpreter() {
        for dict in [false, true] {
            let c = catalog(dict);
            let p = compile_sql(
                "SELECT url, SUM(ms) FROM access GROUP BY url",
                &c.schemas(),
            )
            .unwrap();
            let compiled = run_compiled(&p, &c, None).unwrap();
            let reference = local::run(&p, &c).unwrap();
            assert!(
                compiled
                    .result()
                    .unwrap()
                    .bag_eq(reference.result().unwrap()),
                "dict={dict}"
            );
        }
    }

    #[test]
    fn non_idiomatic_programs_fall_back() {
        let c = catalog(false);
        let p = compile_sql("SELECT url FROM access", &c.schemas()).unwrap();
        assert_eq!(recognize(&p), None);
        let out = run_compiled(&p, &c, None).unwrap();
        assert_eq!(out.result().unwrap().len(), 5);
    }

    struct FakeKernels;
    impl KernelExec for FakeKernels {
        fn group_count(&self, keys: &[i64], num_keys: usize) -> Result<Vec<i64>> {
            let mut c = vec![0i64; num_keys];
            for &k in keys {
                if k >= 0 && (k as usize) < num_keys {
                    c[k as usize] += 1;
                }
            }
            Ok(c)
        }
        fn group_sum(&self, keys: &[i64], vals: &[f64], num_keys: usize) -> Result<Vec<f64>> {
            let mut s = vec![0f64; num_keys];
            for (&k, &v) in keys.iter().zip(vals) {
                if k >= 0 && (k as usize) < num_keys {
                    s[k as usize] += v;
                }
            }
            Ok(s)
        }
    }

    #[test]
    fn kernel_hook_is_used_for_dict_encoded_tables() {
        let c = catalog(true);
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let out = run_compiled(&p, &c, Some(&FakeKernels)).unwrap();
        assert!(out.stats.kernel_calls > 0);
        let reference = local::run(&p, &c).unwrap();
        assert!(out.result().unwrap().bag_eq(reference.result().unwrap()));
    }
}
