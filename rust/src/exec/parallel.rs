//! In-process parallel execution of `forall` loops.
//!
//! The coordinator (crate::coordinator) is the *distributed* runtime; this
//! module is its shared-memory little sibling — the OpenMP half of the
//! paper's "MPI and OpenMP" generated code.
//!
//! Programs supported by the vectorized tier are compiled **once**
//! (`exec::compile`) and the slot-resolved program is shared read-only by
//! every worker: a chunked worker pool pulls batches of `forall`
//! iterations from a shared cursor (dynamic self-scheduling, the
//! in-process analogue of the coordinator's chunk queue), each worker
//! accumulating into a private [`VecState`]. Privatized `count_k` slices
//! write disjoint keys, so the end-of-loop merge is a plain union;
//! [`VecState::absorb`] also stays correct for overlapping commutative
//! adds. Programs outside the vectorized tier fall back to the
//! interpreter-based fan-out below.
//!
//! Compiled hash joins parallelize similarly: the [`JoinHashTable`] is
//! built **once** and shared read-only across the pool while each worker
//! probes one contiguous block of probe-side rows, provided the join
//! body's effects are only commutative accumulator adds and result
//! appends (checked by `join_parallel_safe`; scalar writes, prints and
//! array reads keep the join on the sequential driver). As with the
//! `forall` fan-out, merging per-worker float partials may reorder a
//! floating-point fold across workers.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{Context, Result};

use crate::ir::{Domain, LoopKind, Program, Stmt, Value};
use crate::storage::StorageCatalog;

use super::compile::{compile_program, CStmt, CompiledProgram, ExprProg, Op};
use super::eval::ArrayStore;
use super::local::{ExecStats, Interp, Output};
use super::vector::{JoinHashTable, VecState, BATCH};
use crate::ir::AccumOp;

/// Execute a program, running top-level `forall` range loops on a chunked
/// worker pool (bounded by `max_threads`; `0` is treated as `1`).
pub fn run_parallel(
    program: &Program,
    catalog: &StorageCatalog,
    max_threads: usize,
) -> Result<Output> {
    match compile_program(program, catalog) {
        Some(cp) => run_parallel_compiled(&cp, max_threads),
        None => run_parallel_interp(program, catalog, max_threads),
    }
}

/// Parallel driver for compiled programs: every worker shares the same
/// slot-resolved `CompiledProgram`; `forall` iterations are dealt out in
/// batches from a shared atomic cursor.
pub fn run_parallel_compiled(cp: &CompiledProgram, max_threads: usize) -> Result<Output> {
    let threads = max_threads.max(1);
    let mut master = VecState::new(cp);
    for s in &cp.body {
        match s {
            CStmt::Range {
                kind: LoopKind::Forall,
                slot,
                lo,
                hi,
                body,
            } => {
                let lo = master
                    .eval_value(cp, lo)?
                    .as_int()
                    .context("forall lo")?;
                let hi = master
                    .eval_value(cp, hi)?
                    .as_int()
                    .context("forall hi")?;
                if hi < lo {
                    continue; // empty iteration space
                }
                let iters: Vec<i64> = (lo..=hi).collect();
                let workers = threads.min(iters.len()).max(1);
                // ~4 batches per worker balances load without contending
                // on the cursor; never zero.
                let batch = iters.len().div_ceil(workers * 4).max(1);
                let next = AtomicUsize::new(0);
                let slot = *slot;

                let states: Vec<Result<VecState>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..workers)
                        .map(|_| {
                            let next = &next;
                            let iters = &iters;
                            scope.spawn(move || -> Result<VecState> {
                                let mut st = VecState::new(cp);
                                loop {
                                    let start = next.fetch_add(batch, Ordering::Relaxed);
                                    if start >= iters.len() {
                                        break;
                                    }
                                    let end = (start + batch).min(iters.len());
                                    for &k in &iters[start..end] {
                                        st.scalars[slot] = Value::Int(k);
                                        st.exec_stmts(cp, body)?;
                                    }
                                }
                                Ok(st)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("forall worker panicked"))
                        .collect()
                });

                for r in states {
                    master.absorb(r?);
                }
            }
            CStmt::Join(jl)
                if threads > 1 && jl.outer.len() > BATCH && join_parallel_safe(jl) =>
            {
                // Build once, probe everywhere: the hash table is shared
                // read-only. Each worker gets ONE contiguous block of
                // probe-side rows (probe cost is uniform per row, and a
                // single probe_join call keeps the fused per-match
                // kernels eligible for the worker's whole range — with
                // batch stealing only the first stolen range would fuse).
                let build = JoinHashTable::build(&jl.build, jl.build_key);
                master.stats.index_builds += 1;
                let len = jl.outer.len();
                let workers = threads.min(len.div_ceil(BATCH)).max(1);
                let build = &build;
                // Workers see the master's current scalar state (read-only
                // — the safety check rejects scalar writes in the body).
                let scalars = master.scalars.clone();
                let scalars = &scalars;

                let states: Vec<Result<VecState>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..workers)
                        .map(|w| {
                            scope.spawn(move || -> Result<VecState> {
                                let mut st = VecState::new(cp);
                                st.scalars.clone_from(scalars);
                                let (lo, hi) =
                                    super::local::block_bounds(len, workers, w);
                                st.probe_join(cp, jl, build, lo, hi)?;
                                Ok(st)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("join worker panicked"))
                        .collect()
                });

                for r in states {
                    master.absorb(r?);
                }
            }
            other => master.exec_stmts(cp, std::slice::from_ref(other))?,
        }
    }
    Ok(master.finish(cp))
}

/// True when a compiled join can fan out across workers: the body's
/// effects are only commutative accumulator adds and result appends —
/// the effects [`VecState::absorb`] merges losslessly — and no involved
/// expression reads accumulator arrays (a worker would observe its own
/// partial state instead of the global one). Scalar assignments, prints,
/// nested loops and partitioned outers keep the join on the sequential
/// driver.
fn join_parallel_safe(jl: &super::compile::JoinLoop) -> bool {
    jl.partition.is_none()
        && expr_safe(&jl.probe_key)
        && match &jl.outer_filter {
            Some((_, p)) => expr_safe(p),
            None => true,
        }
        && join_body_parallel_safe(&jl.body)
}

fn expr_safe(p: &ExprProg) -> bool {
    p.ops
        .iter()
        .all(|o| !matches!(o, Op::ReadArray { .. } | Op::Sum { .. }))
}

fn join_body_parallel_safe(body: &[CStmt]) -> bool {
    body.iter().all(|s| match s {
        CStmt::Result { tuple, .. } => tuple.iter().all(expr_safe),
        CStmt::Accum { idx, op, value, .. } => {
            *op == AccumOp::Add && idx.iter().all(expr_safe) && expr_safe(value)
        }
        CStmt::If { cond, then, els } => {
            expr_safe(cond) && join_body_parallel_safe(then) && join_body_parallel_safe(els)
        }
        _ => false,
    })
}

/// Interpreter-based fallback for programs the vectorized tier does not
/// support (value partitions, distinct-value domains, ...). Each worker
/// runs a private `Interp` over a static share of the iterations.
pub(crate) fn run_parallel_interp(
    program: &Program,
    catalog: &StorageCatalog,
    max_threads: usize,
) -> Result<Output> {
    let mut master = Interp::new(program, catalog);
    for s in &program.body {
        match s {
            Stmt::Loop(l) if l.kind == LoopKind::Forall => {
                if let Domain::Range { lo, hi } = &l.domain {
                    // Evaluate bounds in the master environment.
                    let lo = super::eval::eval(lo, &master.env, &master.arrays, program)?
                        .as_int()
                        .context("forall lo")?;
                    let hi = super::eval::eval(hi, &master.env, &master.arrays, program)?
                        .as_int()
                        .context("forall hi")?;
                    if hi < lo {
                        continue; // empty range: spawning would div_ceil(0)
                    }
                    let iters: Vec<i64> = (lo..=hi).collect();

                    // Fan out: each worker runs with a PRIVATE, empty
                    // accumulator store. This is sound for the programs
                    // the parallelizing transforms generate: privatized
                    // bodies only touch their own k-slice of each array
                    // and never read pre-loop accumulator state.
                    let chunk = iters.len().div_ceil(max_threads.max(1)).max(1);
                    let chunks: Vec<Vec<i64>> =
                        iters.chunks(chunk).map(|c| c.to_vec()).collect();
                    type WorkerOut =
                        (ArrayStore, BTreeMap<String, crate::ir::Multiset>, ExecStats, Vec<String>);
                    let results: Vec<Result<WorkerOut>> = std::thread::scope(|scope| {
                        let handles: Vec<_> = chunks
                            .iter()
                            .map(|chunk| {
                                let body = &l.body;
                                let var = &l.var;
                                scope.spawn(move || {
                                    let mut worker = Interp::new(program, catalog);
                                    for &k in chunk {
                                        worker.env.push_var(var, Value::Int(k));
                                        let r = worker.run_body(body);
                                        worker.env.pop_var();
                                        r?;
                                    }
                                    Ok((
                                        worker.arrays,
                                        worker.results,
                                        worker.stats,
                                        worker.prints,
                                    ))
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .map(|h| h.join().expect("forall worker panicked"))
                            .collect()
                    });

                    // Merge worker stores into the master: privatized
                    // slices are disjoint, and any residual overlap is a
                    // commutative Add (merge_add handles both). Prints
                    // append in chunk order, matching the compiled path.
                    for r in results {
                        let (arrays, results, stats, prints) = r?;
                        master.arrays.merge_add(arrays);
                        for (name, m) in results {
                            if let Some(dst) = master.results.get_mut(&name) {
                                for row in m.into_rows() {
                                    dst.push(row);
                                }
                            }
                        }
                        master.stats.rows_visited += stats.rows_visited;
                        master.stats.index_builds += stats.index_builds;
                        master.prints.extend(prints);
                    }
                    continue;
                }
                // Non-range forall: run sequentially (rare).
                master.run_body(std::slice::from_ref(s))?;
            }
            other => master.run_body(std::slice::from_ref(other))?,
        }
    }
    Ok(master.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Expr;
    use crate::sql::compile_sql;
    use crate::transform::{DirectPartition, Pass, PassCtx};
    use crate::workload::{access_log, AccessLogSpec};

    fn setup(rows: usize) -> (Program, StorageCatalog) {
        let m = access_log(&AccessLogSpec {
            rows,
            urls: 200,
            skew: 1.1,
            seed: 3,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let mut p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        DirectPartition
            .run(&mut p, &PassCtx::new().with_processors(8))
            .unwrap();
        (p, c)
    }

    #[test]
    fn parallel_forall_matches_sequential() {
        let (p, c) = setup(20_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        for threads in [1, 2, 4, 8] {
            let par = run_parallel(&p, &c, threads).unwrap();
            assert!(
                par.result().unwrap().bag_eq(seq.result().unwrap()),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn interp_fallback_matches_sequential() {
        let (p, c) = setup(5_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        let par = run_parallel_interp(&p, &c, 4).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
    }

    #[test]
    fn parallel_handles_programs_without_forall() {
        let m = access_log(&AccessLogSpec {
            rows: 100,
            urls: 10,
            skew: 1.0,
            seed: 1,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let p = compile_sql("SELECT url FROM access", &c.schemas()).unwrap();
        let out = run_parallel(&p, &c, 4).unwrap();
        assert_eq!(out.result().unwrap().len(), 100);
    }

    #[test]
    fn zero_max_threads_does_not_panic() {
        let (p, c) = setup(2_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        // Both drivers clamp to one worker.
        let par = run_parallel(&p, &c, 0).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
        let par = run_parallel_interp(&p, &c, 0).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
    }

    #[test]
    fn empty_forall_range_does_not_panic() {
        // forall k = 1..=0 over the accumulation: zero iterations (the
        // emit loop still runs, so compare against the interpreter rather
        // than asserting emptiness).
        let (mut p, c) = setup(500);
        if let Stmt::Loop(forall) = &mut p.body[0] {
            if let Domain::Range { hi, .. } = &mut forall.domain {
                *hi = Expr::int(0);
            }
        }
        let seq = super::super::local::run(&p, &c).unwrap();
        let out = run_parallel(&p, &c, 4).unwrap();
        assert!(out.result().unwrap().bag_eq(seq.result().unwrap()));
        let out = run_parallel_interp(&p, &c, 4).unwrap();
        assert!(out.result().unwrap().bag_eq(seq.result().unwrap()));
    }

    fn join_setup(arows: usize, brows: usize) -> (StorageCatalog, Program, Program) {
        use crate::ir::{DataType, Multiset, Schema, Value};
        let mut rng = crate::util::Rng::new(21);
        let mut a = Multiset::new(Schema::new(vec![
            ("b_id", DataType::Int),
            ("g", DataType::Str),
        ]));
        for _ in 0..arows {
            a.push(vec![
                Value::Int(rng.range(0, brows as i64 * 2)),
                Value::str(format!("g{}", rng.below(16))),
            ]);
        }
        let mut b = Multiset::new(Schema::new(vec![("id", DataType::Int)]));
        for i in 0..brows {
            b.push(vec![Value::Int(i as i64)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("A", &a).unwrap();
        c.insert_multiset("B", &b).unwrap();
        let join = compile_sql(
            "SELECT A.g, B.id FROM A JOIN B ON A.b_id = B.id",
            &c.schemas(),
        )
        .unwrap();
        let agg = compile_sql(
            "SELECT g, COUNT(g) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
            &c.schemas(),
        )
        .unwrap();
        (c, join, agg)
    }

    #[test]
    fn parallel_hash_join_matches_sequential() {
        let (c, join, agg) = join_setup(20_000, 500);
        for p in [&join, &agg] {
            let seq = super::super::local::run(p, &c).unwrap();
            for threads in [1, 2, 4, 8] {
                let par = run_parallel(p, &c, threads).unwrap();
                assert!(
                    par.result().unwrap().bag_eq(seq.result().unwrap()),
                    "threads={threads}"
                );
            }
        }
    }

    #[test]
    fn parallel_join_tags_hash_join_idiom() {
        let (c, join, _) = join_setup(10_000, 200);
        let par = run_parallel(&join, &c, 4).unwrap();
        assert!(
            par.stats.idioms.contains(&"vec.hash_join".to_string()),
            "{:?}",
            par.stats.idioms
        );
    }

    #[test]
    fn tiny_join_runs_sequentially_and_matches() {
        // Below the fan-out threshold the join stays on the master state.
        let (c, join, agg) = join_setup(50, 10);
        for p in [&join, &agg] {
            let seq = super::super::local::run(p, &c).unwrap();
            let par = run_parallel(p, &c, 8).unwrap();
            assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
        }
    }

    #[test]
    fn parallel_is_faster_on_big_input() {
        // Not a strict assertion (CI noise), but sanity-log the ratio.
        let (p, c) = setup(200_000);
        let t0 = std::time::Instant::now();
        let _ = super::super::local::run(&p, &c).unwrap();
        let seq_t = t0.elapsed();
        let t0 = std::time::Instant::now();
        let _ = run_parallel(&p, &c, 8).unwrap();
        let par_t = t0.elapsed();
        eprintln!("seq {seq_t:?} vs par {par_t:?}");
    }
}
