//! In-process parallel execution of `forall` loops.
//!
//! The coordinator (crate::coordinator) is the *distributed* runtime; this
//! module is its shared-memory little sibling — the OpenMP half of the
//! paper's "MPI and OpenMP" generated code.
//!
//! Programs supported by the vectorized tier are compiled **once**
//! (`exec::compile`) and the slot-resolved program is shared read-only by
//! every worker: a chunked worker pool pulls batches of `forall`
//! iterations from a shared cursor (dynamic self-scheduling, the
//! in-process analogue of the coordinator's chunk queue), each worker
//! accumulating into a private [`VecState`]. Privatized `count_k` slices
//! write disjoint keys, so the end-of-loop merge is a plain union;
//! [`VecState::absorb`] also stays correct for overlapping commutative
//! adds. Programs outside the vectorized tier fall back to the
//! interpreter-based fan-out below.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{Context, Result};

use crate::ir::{Domain, LoopKind, Program, Stmt, Value};
use crate::storage::StorageCatalog;

use super::compile::{compile_program, CStmt, CompiledProgram};
use super::eval::ArrayStore;
use super::local::{ExecStats, Interp, Output};
use super::vector::VecState;

/// Execute a program, running top-level `forall` range loops on a chunked
/// worker pool (bounded by `max_threads`; `0` is treated as `1`).
pub fn run_parallel(
    program: &Program,
    catalog: &StorageCatalog,
    max_threads: usize,
) -> Result<Output> {
    match compile_program(program, catalog) {
        Some(cp) => run_parallel_compiled(&cp, max_threads),
        None => run_parallel_interp(program, catalog, max_threads),
    }
}

/// Parallel driver for compiled programs: every worker shares the same
/// slot-resolved `CompiledProgram`; `forall` iterations are dealt out in
/// batches from a shared atomic cursor.
pub fn run_parallel_compiled(cp: &CompiledProgram, max_threads: usize) -> Result<Output> {
    let threads = max_threads.max(1);
    let mut master = VecState::new(cp);
    for s in &cp.body {
        match s {
            CStmt::Range {
                kind: LoopKind::Forall,
                slot,
                lo,
                hi,
                body,
            } => {
                let lo = master
                    .eval_value(cp, lo)?
                    .as_int()
                    .context("forall lo")?;
                let hi = master
                    .eval_value(cp, hi)?
                    .as_int()
                    .context("forall hi")?;
                if hi < lo {
                    continue; // empty iteration space
                }
                let iters: Vec<i64> = (lo..=hi).collect();
                let workers = threads.min(iters.len()).max(1);
                // ~4 batches per worker balances load without contending
                // on the cursor; never zero.
                let batch = iters.len().div_ceil(workers * 4).max(1);
                let next = AtomicUsize::new(0);
                let slot = *slot;

                let states: Vec<Result<VecState>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..workers)
                        .map(|_| {
                            let next = &next;
                            let iters = &iters;
                            scope.spawn(move || -> Result<VecState> {
                                let mut st = VecState::new(cp);
                                loop {
                                    let start = next.fetch_add(batch, Ordering::Relaxed);
                                    if start >= iters.len() {
                                        break;
                                    }
                                    let end = (start + batch).min(iters.len());
                                    for &k in &iters[start..end] {
                                        st.scalars[slot] = Value::Int(k);
                                        st.exec_stmts(cp, body)?;
                                    }
                                }
                                Ok(st)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("forall worker panicked"))
                        .collect()
                });

                for r in states {
                    master.absorb(r?);
                }
            }
            other => master.exec_stmts(cp, std::slice::from_ref(other))?,
        }
    }
    Ok(master.finish(cp))
}

/// Interpreter-based fallback for programs the vectorized tier does not
/// support (value partitions, joins, ...). Each worker runs a private
/// `Interp` over a static share of the iterations.
pub(crate) fn run_parallel_interp(
    program: &Program,
    catalog: &StorageCatalog,
    max_threads: usize,
) -> Result<Output> {
    let mut master = Interp::new(program, catalog);
    for s in &program.body {
        match s {
            Stmt::Loop(l) if l.kind == LoopKind::Forall => {
                if let Domain::Range { lo, hi } = &l.domain {
                    // Evaluate bounds in the master environment.
                    let lo = super::eval::eval(lo, &master.env, &master.arrays, program)?
                        .as_int()
                        .context("forall lo")?;
                    let hi = super::eval::eval(hi, &master.env, &master.arrays, program)?
                        .as_int()
                        .context("forall hi")?;
                    if hi < lo {
                        continue; // empty range: spawning would div_ceil(0)
                    }
                    let iters: Vec<i64> = (lo..=hi).collect();

                    // Fan out: each worker runs with a PRIVATE, empty
                    // accumulator store. This is sound for the programs
                    // the parallelizing transforms generate: privatized
                    // bodies only touch their own k-slice of each array
                    // and never read pre-loop accumulator state.
                    let chunk = iters.len().div_ceil(max_threads.max(1)).max(1);
                    let chunks: Vec<Vec<i64>> =
                        iters.chunks(chunk).map(|c| c.to_vec()).collect();
                    type WorkerOut =
                        (ArrayStore, BTreeMap<String, crate::ir::Multiset>, ExecStats, Vec<String>);
                    let results: Vec<Result<WorkerOut>> = std::thread::scope(|scope| {
                        let handles: Vec<_> = chunks
                            .iter()
                            .map(|chunk| {
                                let body = &l.body;
                                let var = &l.var;
                                scope.spawn(move || {
                                    let mut worker = Interp::new(program, catalog);
                                    for &k in chunk {
                                        worker.env.push_var(var, Value::Int(k));
                                        let r = worker.run_body(body);
                                        worker.env.pop_var();
                                        r?;
                                    }
                                    Ok((
                                        worker.arrays,
                                        worker.results,
                                        worker.stats,
                                        worker.prints,
                                    ))
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .map(|h| h.join().expect("forall worker panicked"))
                            .collect()
                    });

                    // Merge worker stores into the master: privatized
                    // slices are disjoint, and any residual overlap is a
                    // commutative Add (merge_add handles both). Prints
                    // append in chunk order, matching the compiled path.
                    for r in results {
                        let (arrays, results, stats, prints) = r?;
                        master.arrays.merge_add(arrays);
                        for (name, m) in results {
                            if let Some(dst) = master.results.get_mut(&name) {
                                for row in m.into_rows() {
                                    dst.push(row);
                                }
                            }
                        }
                        master.stats.rows_visited += stats.rows_visited;
                        master.stats.index_builds += stats.index_builds;
                        master.prints.extend(prints);
                    }
                    continue;
                }
                // Non-range forall: run sequentially (rare).
                master.run_body(std::slice::from_ref(s))?;
            }
            other => master.run_body(std::slice::from_ref(other))?,
        }
    }
    Ok(master.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Expr;
    use crate::sql::compile_sql;
    use crate::transform::{DirectPartition, Pass, PassCtx};
    use crate::workload::{access_log, AccessLogSpec};

    fn setup(rows: usize) -> (Program, StorageCatalog) {
        let m = access_log(&AccessLogSpec {
            rows,
            urls: 200,
            skew: 1.1,
            seed: 3,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let mut p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        DirectPartition
            .run(&mut p, &PassCtx::new().with_processors(8))
            .unwrap();
        (p, c)
    }

    #[test]
    fn parallel_forall_matches_sequential() {
        let (p, c) = setup(20_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        for threads in [1, 2, 4, 8] {
            let par = run_parallel(&p, &c, threads).unwrap();
            assert!(
                par.result().unwrap().bag_eq(seq.result().unwrap()),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn interp_fallback_matches_sequential() {
        let (p, c) = setup(5_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        let par = run_parallel_interp(&p, &c, 4).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
    }

    #[test]
    fn parallel_handles_programs_without_forall() {
        let m = access_log(&AccessLogSpec {
            rows: 100,
            urls: 10,
            skew: 1.0,
            seed: 1,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let p = compile_sql("SELECT url FROM access", &c.schemas()).unwrap();
        let out = run_parallel(&p, &c, 4).unwrap();
        assert_eq!(out.result().unwrap().len(), 100);
    }

    #[test]
    fn zero_max_threads_does_not_panic() {
        let (p, c) = setup(2_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        // Both drivers clamp to one worker.
        let par = run_parallel(&p, &c, 0).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
        let par = run_parallel_interp(&p, &c, 0).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
    }

    #[test]
    fn empty_forall_range_does_not_panic() {
        // forall k = 1..=0 over the accumulation: zero iterations (the
        // emit loop still runs, so compare against the interpreter rather
        // than asserting emptiness).
        let (mut p, c) = setup(500);
        if let Stmt::Loop(forall) = &mut p.body[0] {
            if let Domain::Range { hi, .. } = &mut forall.domain {
                *hi = Expr::int(0);
            }
        }
        let seq = super::super::local::run(&p, &c).unwrap();
        let out = run_parallel(&p, &c, 4).unwrap();
        assert!(out.result().unwrap().bag_eq(seq.result().unwrap()));
        let out = run_parallel_interp(&p, &c, 4).unwrap();
        assert!(out.result().unwrap().bag_eq(seq.result().unwrap()));
    }

    #[test]
    fn parallel_is_faster_on_big_input() {
        // Not a strict assertion (CI noise), but sanity-log the ratio.
        let (p, c) = setup(200_000);
        let t0 = std::time::Instant::now();
        let _ = super::super::local::run(&p, &c).unwrap();
        let seq_t = t0.elapsed();
        let t0 = std::time::Instant::now();
        let _ = run_parallel(&p, &c, 8).unwrap();
        let par_t = t0.elapsed();
        eprintln!("seq {seq_t:?} vs par {par_t:?}");
    }
}
