//! In-process parallel execution of `forall` loops.
//!
//! The coordinator (crate::coordinator) is the *distributed* runtime; this
//! module is its shared-memory little sibling — the OpenMP half of the
//! paper's "MPI and OpenMP" generated code. Each top-level `forall`
//! iteration runs on its own thread with a private accumulator store
//! (the privatized `count_k` arrays of §IV write disjoint slices, so the
//! end-of-loop merge is a plain union; `merge_add` also stays correct for
//! overlapping commutative adds). Result-multiset appends concatenate —
//! bag semantics make the interleaving irrelevant.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::ir::{Domain, LoopKind, Program, Stmt, Value};
use crate::storage::StorageCatalog;

use super::eval::ArrayStore;
use super::local::{ExecStats, Interp, Output};

/// Execute a program, running top-level `forall` range loops with one
/// thread per iteration (bounded by `max_threads`).
pub fn run_parallel(
    program: &Program,
    catalog: &StorageCatalog,
    max_threads: usize,
) -> Result<Output> {
    let mut master = Interp::new(program, catalog);
    for s in &program.body {
        match s {
            Stmt::Loop(l) if l.kind == LoopKind::Forall => {
                if let Domain::Range { lo, hi } = &l.domain {
                    // Evaluate bounds in the master environment.
                    let lo = super::eval::eval(lo, &master.env, &master.arrays, program)?
                        .as_int()
                        .context("forall lo")?;
                    let hi = super::eval::eval(hi, &master.env, &master.arrays, program)?
                        .as_int()
                        .context("forall hi")?;
                    let iters: Vec<i64> = (lo..=hi).collect();

                    // Fan out: each worker runs with a PRIVATE, empty
                    // accumulator store. This is sound for the programs
                    // the parallelizing transforms generate: privatized
                    // bodies only touch their own k-slice of each array
                    // and never read pre-loop accumulator state.
                    let chunks: Vec<Vec<i64>> = iters
                        .chunks(iters.len().div_ceil(max_threads.max(1)))
                        .map(|c| c.to_vec())
                        .collect();
                    let results: Vec<Result<(ArrayStore, BTreeMap<String, crate::ir::Multiset>, ExecStats)>> =
                        std::thread::scope(|scope| {
                            let handles: Vec<_> = chunks
                                .iter()
                                .map(|chunk| {
                                    let body = &l.body;
                                    let var = &l.var;
                                    scope.spawn(move || {
                                        let mut worker = Interp::new(program, catalog);
                                        for &k in chunk {
                                            worker.env.push_var(var, Value::Int(k));
                                            let r = worker.run_body(body);
                                            worker.env.pop_var();
                                            r?;
                                        }
                                        Ok((worker.arrays, worker.results, worker.stats))
                                    })
                                })
                                .collect();
                            handles
                                .into_iter()
                                .map(|h| h.join().expect("forall worker panicked"))
                                .collect()
                        });

                    // Merge worker stores into the master: privatized
                    // slices are disjoint, and any residual overlap is a
                    // commutative Add (merge_add handles both).
                    for r in results {
                        let (arrays, results, stats) = r?;
                        master.arrays.merge_add(arrays);
                        for (name, m) in results {
                            if let Some(dst) = master.results.get_mut(&name) {
                                for row in m.into_rows() {
                                    dst.push(row);
                                }
                            }
                        }
                        master.stats.rows_visited += stats.rows_visited;
                        master.stats.index_builds += stats.index_builds;
                    }
                    continue;
                }
                // Non-range forall: run sequentially (rare).
                master.run_body(std::slice::from_ref(s))?;
            }
            other => master.run_body(std::slice::from_ref(other))?,
        }
    }
    Ok(master.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sql::compile_sql;
    use crate::transform::{DirectPartition, Pass, PassCtx};
    use crate::workload::{access_log, AccessLogSpec};

    fn setup(rows: usize) -> (Program, StorageCatalog) {
        let m = access_log(&AccessLogSpec {
            rows,
            urls: 200,
            skew: 1.1,
            seed: 3,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let mut p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        DirectPartition
            .run(&mut p, &PassCtx::new().with_processors(8))
            .unwrap();
        (p, c)
    }

    #[test]
    fn parallel_forall_matches_sequential() {
        let (p, c) = setup(20_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        for threads in [1, 2, 4, 8] {
            let par = run_parallel(&p, &c, threads).unwrap();
            assert!(
                par.result().unwrap().bag_eq(seq.result().unwrap()),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn parallel_handles_programs_without_forall() {
        let m = access_log(&AccessLogSpec {
            rows: 100,
            urls: 10,
            skew: 1.0,
            seed: 1,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let p = compile_sql("SELECT url FROM access", &c.schemas()).unwrap();
        let out = run_parallel(&p, &c, 4).unwrap();
        assert_eq!(out.result().unwrap().len(), 100);
    }

    #[test]
    fn parallel_is_faster_on_big_input() {
        // Not a strict assertion (CI noise), but sanity-log the ratio.
        let (p, c) = setup(200_000);
        let t0 = std::time::Instant::now();
        let _ = super::super::local::run(&p, &c).unwrap();
        let seq_t = t0.elapsed();
        let t0 = std::time::Instant::now();
        let _ = run_parallel(&p, &c, 8).unwrap();
        let par_t = t0.elapsed();
        eprintln!("seq {seq_t:?} vs par {par_t:?}");
    }
}
