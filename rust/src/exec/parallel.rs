//! In-process morsel-driven parallel execution of compiled programs.
//!
//! The coordinator (crate::coordinator) is the *distributed* runtime; this
//! module is its shared-memory little sibling — the OpenMP half of the
//! paper's "MPI and OpenMP" generated code.
//!
//! Programs supported by the vectorized tier are compiled **once**
//! (`exec::compile`) and the slot-resolved program is shared read-only by
//! every worker. All fan-out flows through one morsel-dispatch
//! abstraction (`morsel_dispatch` below): workers pull chunks of the
//! iteration space from a [`SharedScheduler`] driving the §III-A2 loop
//! scheduling policies (GSS by default; selectable per run via
//! [`run_parallel_with_policy`]), time each chunk for the feedback-guided
//! policy, and accumulate into private [`VecState`]s that the master
//! merges via [`VecState::absorb`]. Three loop shapes fan out:
//!
//! * **`forall` range loops** — scheduled per iteration (each iteration
//!   is typically a whole inner scan). Bodies are assumed privatized by
//!   the parallelizing transforms (disjoint `count_k` slices or
//!   commutative adds), as before.
//! * **`forelem` scans** — the bread-and-butter SQL shape (scans,
//!   filters, group-by accumulation loops), scheduled in [`BATCH`]-row
//!   morsels when `scan_parallel_safe` proves the body's only effects are
//!   commutative accumulator adds and result appends. The fused
//!   `vec.count`/`vec.sum` batch kernels fire per-morsel through a
//!   per-worker incremental aggregation state, exactly as they do
//!   sequentially.
//! * **compiled hash joins** — the [`JoinHashTable`] is built **once**
//!   and shared read-only while workers probe morsels of the outer side
//!   (`join_parallel_safe` gates the body). Joins with a fused per-match
//!   aggregation pin [`Policy::StaticBlock`] so each worker probes one
//!   contiguous range — a fragmented schedule would fuse only the first
//!   chunk per worker.
//!
//! Ineligible bodies (scalar writes, prints, accumulator reads, distinct
//! or partitioned iteration) run sequentially on the master state, so
//! print order and scalar results stay identical to the interpreter.
//! Eligible scans and join probes additionally pass the optimizer's
//! spin-up gate (`opt::should_fan_out`): iteration spaces too small to
//! amortize worker startup stay sequential and tag
//! `opt.small_scan_seq` / `opt.small_join_seq`.
//! Merging per-worker float partials may reorder a floating-point fold
//! across workers; integer aggregates are exact. A successful fan-out
//! pushes `"vec.morsel"` plus the active policy (e.g. `"sched.gss"`)
//! into [`ExecStats::idioms`].
//!
//! Dispatch is cache- and affinity-aware by default: the scheduler runs
//! through [`SharedScheduler::with_affinity`], so each worker pulls the
//! range adjacent to its last-completed chunk (its column windows stay
//! hot) and steals only when its neighborhood is drained, tagging
//! `"sched.affinity"` when an adjacent pull was observed; pass
//! `affinity = false` to [`run_parallel_with_opts`] for the pure global
//! policy order. Worker-private [`VecState`]s are padded to cache-line
//! boundaries so neighboring workers' accumulator stores never
//! false-share a line, and `sched::pin_worker` best-effort-pins worker
//! threads to cores when the off-by-default `core_affinity` feature is
//! enabled.
//!
//! Programs outside the vectorized tier fall back to the
//! interpreter-based fan-out at the bottom of this module.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::ir::{Domain, LoopKind, Program, Stmt, Value};
use crate::sched::{Chunk, Policy, SharedScheduler};
use crate::storage::StorageCatalog;

use super::compile::{
    compile_program, distinct_emit_parallel_safe, emit_parallel_safe, join_parallel_safe,
    scan_parallel_safe, CStmt, CompiledProgram, ScanLoop,
};
use super::eval::ArrayStore;
use super::index::DistinctIndex;
use super::local::{ExecStats, Interp, Output};
use super::vector::{EmitChunk, FastAggState, JoinHashTable, TopKSet, VecState, BATCH};

/// Default scheduling policy for the in-process pool (§III-A2's guided
/// self-scheduling: large chunks early, small chunks to balance the tail).
pub const DEFAULT_POLICY: Policy = Policy::Gss;

/// The single authoritative `max_threads` clamp, shared by every driver
/// in this module: `0` means "caller did not decide" and runs
/// sequentially, exactly like `1`.
fn clamp_threads(max_threads: usize) -> usize {
    max_threads.max(1)
}

/// Execute a program on a morsel-driven worker pool (bounded by
/// `max_threads`; `0` is treated as `1`) under the default GSS policy.
pub fn run_parallel(
    program: &Program,
    catalog: &StorageCatalog,
    max_threads: usize,
) -> Result<Output> {
    run_parallel_with_policy(program, catalog, max_threads, DEFAULT_POLICY)
}

/// [`run_parallel`] with an explicit §III-A2 scheduling policy. Programs
/// the vectorized tier cannot compile fall back to the interpreter-based
/// fan-out, which uses static chunking (the policies need the compiled
/// form's cheap chunk boundaries to pay off).
pub fn run_parallel_with_policy(
    program: &Program,
    catalog: &StorageCatalog,
    max_threads: usize,
    policy: Policy,
) -> Result<Output> {
    run_parallel_with_opts(program, catalog, max_threads, policy, true)
}

/// [`run_parallel_with_policy`] with the chunk-affinity machinery
/// selectable: `affinity = true` (the default everywhere else) routes
/// the pool through [`SharedScheduler::with_affinity`]; `false` uses the
/// policy's pure global chunk order. The interpreter fallback ignores
/// the flag (it chunks statically either way).
pub fn run_parallel_with_opts(
    program: &Program,
    catalog: &StorageCatalog,
    max_threads: usize,
    policy: Policy,
    affinity: bool,
) -> Result<Output> {
    let mut out = match compile_program(program, catalog) {
        Some(cp) => run_parallel_compiled_with_opts(&cp, max_threads, policy, affinity)?,
        None => run_parallel_interp(program, catalog, max_threads)?,
    };
    out.stats.note_opt_tags(&program.opt_tags);
    Ok(out)
}

/// Parallel driver for compiled programs under the default GSS policy.
pub fn run_parallel_compiled(cp: &CompiledProgram, max_threads: usize) -> Result<Output> {
    run_parallel_compiled_with_policy(cp, max_threads, DEFAULT_POLICY)
}

/// One shared morsel-dispatch job: every worker shares the same
/// slot-resolved `CompiledProgram` and the master's scalar snapshot.
struct MorselJob<'a> {
    cp: &'a CompiledProgram,
    /// Master scalars at loop entry, fanned out read-only (the safety
    /// analyses reject scalar writes in eligible bodies; `forall` bodies
    /// overwrite only their own loop slot).
    scalars: &'a [Value],
    /// Master parameter binding, fanned out read-only: a prepared
    /// statement's per-execution values must survive into every worker
    /// (a fresh `VecState` would only see the compile-time defaults).
    params: &'a [Value],
    /// Size of the scheduled space (iterations for `forall`, [`BATCH`]-row
    /// morsels for scans and join probes).
    units: usize,
    workers: usize,
    policy: Policy,
    /// Route chunks through the affinity-aware scheduler (adjacent-range
    /// pulls per worker) and best-effort-pin worker threads.
    affinity: bool,
}

/// Cache-line-aligned box for worker-private state: per-worker
/// [`VecState`]s (and fused-aggregation contexts) live at least one
/// 64-byte line apart, so the hot per-morsel accumulator stores of
/// neighboring workers never false-share a line.
#[repr(align(64))]
struct CacheAligned<T>(T);

/// The shared morsel-dispatch driver unifying the `forall`, scan and join
/// fan-outs: `workers` scoped threads pull [`Chunk`]s of `[0, units)`
/// from one [`SharedScheduler`], timing each chunk for the
/// feedback-guided policy. Each worker owns a private [`VecState`]
/// (seeded with the master's scalars) plus a caller-defined per-worker
/// context `C` (`init` → per-chunk `body` → `finish`); the caller merges
/// the returned states via [`VecState::absorb`].
fn morsel_dispatch<C>(
    job: MorselJob<'_>,
    init: impl Fn(&mut VecState) -> C + Sync,
    body: impl Fn(&mut VecState, &mut C, Chunk) -> Result<()> + Sync,
    finish: impl Fn(&mut VecState, C) -> Result<()> + Sync,
) -> Result<(Vec<VecState>, bool)> {
    let MorselJob {
        cp,
        scalars,
        params,
        units,
        workers,
        policy,
        affinity,
    } = job;
    let sched = if affinity {
        SharedScheduler::with_affinity(policy, units, workers)
    } else {
        SharedScheduler::new(policy, units, workers)
    };
    let sched = &sched;
    let (init, body, finish) = (&init, &body, &finish);
    let states: Vec<Result<VecState>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                scope.spawn(move || -> Result<VecState> {
                    if affinity {
                        let _ = crate::sched::pin_worker(w);
                    }
                    let mut st = CacheAligned(VecState::new(cp));
                    st.0.scalars.clear();
                    st.0.scalars.extend_from_slice(scalars);
                    st.0.set_params(params.to_vec());
                    let mut ctx = CacheAligned(init(&mut st.0));
                    while let Some(chunk) = sched.next_chunk(w) {
                        let t0 = Instant::now();
                        body(&mut st.0, &mut ctx.0, chunk)?;
                        sched.report(w, chunk, t0.elapsed());
                    }
                    finish(&mut st.0, ctx.0)?;
                    Ok(st.0)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("morsel worker panicked"))
            .collect()
    });
    let engaged = sched.affinity_engaged();
    let states: Result<Vec<VecState>> = states.into_iter().collect();
    Ok((states?, engaged))
}

/// True when `v` is the additive identity. Worker-private accumulators
/// merge into the master by plain `Add` ([`VecState::absorb`]), so every
/// array an eligible body writes must start from zero — otherwise each
/// worker's `or_insert(init)` would contribute the init once per worker
/// instead of once overall.
fn zero_init(v: &Value) -> bool {
    match v {
        Value::Int(0) => true,
        Value::Float(f) => f.to_bits() == 0f64.to_bits(),
        _ => false,
    }
}

/// All accumulator arrays written anywhere in `body` (including nested
/// loops — `forall` bodies wrap scans) have a zero initial value. Also
/// gates the serving pool's fan-out (`crate::serve`).
pub(crate) fn zero_init_accums(cp: &CompiledProgram, body: &[CStmt]) -> bool {
    body.iter().all(|s| match s {
        CStmt::Accum { array, .. } => zero_init(&cp.array_inits[*array]),
        CStmt::If { then, els, .. } => {
            zero_init_accums(cp, then) && zero_init_accums(cp, els)
        }
        CStmt::Range { body, .. } => zero_init_accums(cp, body),
        CStmt::Scan(sl) => zero_init_accums(cp, &sl.body),
        CStmt::Join(jl) => zero_init_accums(cp, &jl.body),
        _ => true,
    })
}

/// Parallel driver for compiled programs: top-level `forall` loops,
/// eligible `forelem` scans and compiled hash joins fan out through the
/// shared morsel dispatch; everything else runs sequentially on the
/// master state in program order, so the master always holds the
/// complete accumulator state before any statement that reads it.
pub fn run_parallel_compiled_with_policy(
    cp: &CompiledProgram,
    max_threads: usize,
    policy: Policy,
) -> Result<Output> {
    run_parallel_compiled_with_opts(cp, max_threads, policy, true)
}

/// [`run_parallel_compiled_with_policy`] with the chunk-affinity
/// machinery selectable (see [`run_parallel_with_opts`]).
pub fn run_parallel_compiled_with_opts(
    cp: &CompiledProgram,
    max_threads: usize,
    policy: Policy,
    affinity: bool,
) -> Result<Output> {
    run_parallel_compiled_bound(cp, None, max_threads, policy, affinity)
}

/// Parallel driver for a prepared statement's per-execution binding:
/// like [`run_parallel_compiled`], but `Op::LoadParam` slots resolve to
/// `params` instead of the compile-time defaults — on the master *and*
/// every morsel worker. The `serve::Server` execute path for programs
/// big enough to fan out.
pub fn run_parallel_compiled_with_params(
    cp: &CompiledProgram,
    params: Vec<Value>,
    max_threads: usize,
) -> Result<Output> {
    if params.len() != cp.param_names.len() {
        bail!(
            "binding has {} values but the program declares {} parameters",
            params.len(),
            cp.param_names.len()
        );
    }
    run_parallel_compiled_bound(cp, Some(params), max_threads, DEFAULT_POLICY, true)
}

/// The one compiled parallel driver behind every public entry point:
/// `params = None` runs with the compile-time defaults.
fn run_parallel_compiled_bound(
    cp: &CompiledProgram,
    params: Option<Vec<Value>>,
    max_threads: usize,
    policy: Policy,
    affinity: bool,
) -> Result<Output> {
    let threads = clamp_threads(max_threads);
    let mut master = VecState::new(cp);
    if let Some(params) = params {
        master.set_params(params);
    }
    for s in &cp.body {
        match s {
            // `forall` bodies are assumed privatized by the parallelizing
            // transforms, but the worker merge is still add-based: arrays
            // with a non-zero init would count the init once per worker,
            // so those loops run sequentially.
            CStmt::Range {
                kind: LoopKind::Forall,
                slot,
                lo,
                hi,
                body,
            } if threads > 1 && zero_init_accums(cp, body) => {
                let lo = master
                    .eval_value(cp, lo)?
                    .as_int()
                    .context("forall lo")?;
                let hi = master
                    .eval_value(cp, hi)?
                    .as_int()
                    .context("forall hi")?;
                if hi < lo {
                    continue; // empty iteration space
                }
                let n = (hi - lo) as usize + 1;
                let workers = threads.min(n);
                let slot = *slot;
                let (states, engaged) = morsel_dispatch(
                    MorselJob {
                        cp,
                        scalars: &master.scalars,
                        params: &master.params,
                        units: n,
                        workers,
                        policy,
                        affinity,
                    },
                    |_st| (),
                    |st, _ctx, c| {
                        for i in c.lo..c.hi {
                            st.scalars[slot] = Value::Int(lo + i as i64);
                            st.exec_stmts(cp, body)?;
                        }
                        Ok(())
                    },
                    |_st, _ctx| Ok(()),
                )?;
                for st in states {
                    master.absorb(st);
                }
                master.note_idiom("vec.morsel");
                master.note_idiom(&format!("sched.{}", policy.name()));
                if engaged {
                    master.note_idiom("sched.affinity");
                }
            }
            // Ordered/bounded emission (the group-by emit half, or an
            // annotated plain scan): workers run disjoint morsels of the
            // domain — distinct firsts for group-bys — into per-worker
            // bounded heaps seeded with a read-only snapshot of the
            // master's complete accumulator state, then the master
            // k-way-merges the heaps. Sequence numbers are global row
            // positions, so the merged emission equals the sequential
            // `vec.topk` output row-for-row, ties included. This is the
            // bounded case of morsel-driven distinct emission.
            CStmt::Scan(sl) if threads > 1 && emit_parallel_safe(sl) => {
                emit_topk_fanout(cp, sl, &mut master, threads, policy, affinity)?;
            }
            // Unbounded distinct emission (the group-by emit half without
            // ORDER BY/LIMIT): workers run disjoint slices of the
            // distinct-firsts list over a shared snapshot of the master's
            // accumulators and the per-chunk row runs concatenate in
            // chunk order, which equals the sequential emission order.
            CStmt::Scan(sl) if threads > 1 && distinct_emit_parallel_safe(sl) => {
                emit_unbounded_fanout(cp, sl, &mut master, threads, policy, affinity)?;
            }
            CStmt::Scan(sl)
                if threads > 1
                    && scan_parallel_safe(sl)
                    && zero_init_accums(cp, &sl.body) =>
            {
                // Optimizer gate: tables too small to amortize worker
                // spin-up stay on the sequential driver (and say so).
                if !crate::opt::should_fan_out(sl.table.len(), threads) {
                    master.note_idiom("opt.small_scan_seq");
                    master.exec_stmts(cp, std::slice::from_ref(s))?;
                    continue;
                }
                // Equality-filter keys are scope-constant: evaluated once
                // in the master's complete pre-loop state, then fanned
                // out to the workers as a plain value.
                let filter = match &sl.filter {
                    Some((fid, prog)) => Some((*fid, master.eval_value(cp, prog)?)),
                    None => None,
                };
                let filter = &filter;
                let len = sl.table.len();
                let units = len.div_ceil(BATCH);
                let workers = threads.min(units);
                let (states, engaged) = morsel_dispatch(
                    MorselJob {
                        cp,
                        scalars: &master.scalars,
                        params: &master.params,
                        units,
                        workers,
                        policy,
                        affinity,
                    },
                    // Per-worker fused aggregation state, fed one morsel
                    // range per chunk and materialized once at the end
                    // (compile sets `fast` only for filterless,
                    // distinct-free single-accumulation bodies).
                    |_st| sl.fast.and_then(|f| FastAggState::new(&sl.table, f)),
                    |st, fast, c| {
                        let (rlo, rhi) = (c.lo * BATCH, (c.hi * BATCH).min(len));
                        match fast {
                            Some(fa) => {
                                fa.update(rlo, rhi);
                                st.stats.rows_visited += (rhi - rlo) as u64;
                            }
                            None => st.scan_rows(cp, sl, filter.as_ref(), rlo, rhi)?,
                        }
                        Ok(())
                    },
                    |st, fast| {
                        if let Some(fa) = fast {
                            let tag = fa.idiom();
                            let extra = fa.extra_idiom();
                            let simd = fa.simd();
                            let array = sl.fast.expect("ctx implies fast").array();
                            fa.finish(&mut st.arrays[array]);
                            st.note_idiom(tag);
                            if let Some(extra) = extra {
                                st.note_idiom(extra);
                            }
                            if simd {
                                st.note_idiom("vec.simd");
                            }
                        }
                        Ok(())
                    },
                )?;
                for st in states {
                    master.absorb(st);
                }
                master.note_idiom("vec.morsel");
                master.note_idiom(&format!("sched.{}", policy.name()));
                if engaged {
                    master.note_idiom("sched.affinity");
                }
            }
            CStmt::Join(jl)
                if threads > 1
                    && join_parallel_safe(jl)
                    && zero_init_accums(cp, &jl.body) =>
            {
                // Same spin-up gate as scans, keyed on the probe side.
                if !crate::opt::should_fan_out(jl.outer.len(), threads) {
                    master.note_idiom("opt.small_join_seq");
                    master.exec_stmts(cp, std::slice::from_ref(s))?;
                    continue;
                }
                // Build once, probe everywhere: every level's hash table
                // is built by the master and shared read-only across the
                // pool — workers never rebuild a chain level.
                let build = JoinHashTable::build(&jl.build, jl.build_key);
                let deeper: Vec<JoinHashTable> = jl
                    .deeper
                    .iter()
                    .map(|lvl| JoinHashTable::build(&lvl.build, lvl.build_key))
                    .collect();
                master.stats.index_builds += 1 + deeper.len();
                let build = &build;
                let deeper = &deeper[..];
                let len = jl.outer.len();
                let units = len.div_ceil(BATCH);
                let workers = threads.min(units);
                // Fused per-match kernels need one contiguous probe range
                // per worker (a fragmented schedule would fuse only each
                // worker's first chunk), so fused joins pin the static
                // block schedule; generic join bodies honour the
                // requested policy.
                let jpolicy = if jl.fast.is_some() {
                    Policy::StaticBlock
                } else {
                    policy
                };
                let (states, engaged) = morsel_dispatch(
                    MorselJob {
                        cp,
                        scalars: &master.scalars,
                        params: &master.params,
                        units,
                        workers,
                        policy: jpolicy,
                        affinity,
                    },
                    |_st| (),
                    |st, _ctx, c| {
                        st.probe_join(cp, jl, build, deeper, c.lo * BATCH, (c.hi * BATCH).min(len))
                    },
                    |_st, _ctx| Ok(()),
                )?;
                for st in states {
                    master.absorb(st);
                }
                master.note_idiom("vec.morsel");
                master.note_idiom(&format!("sched.{}", jpolicy.name()));
                if engaged {
                    master.note_idiom("sched.affinity");
                }
            }
            other => master.exec_stmts(cp, std::slice::from_ref(other))?,
        }
    }
    Ok(master.finish(cp))
}

/// Morsel-driven fan-out of an ordered/bounded emit scan — the parallel
/// half of the group-by emit loop (and of annotated plain scans). The
/// master's complete accumulator state is shared read-only (one `Arc`,
/// no per-worker copies); workers pull morsels of the emission domain
/// (distinct firsts for group-bys, table rows otherwise) and keep
/// per-worker bounded [`TopK`](super::vector::TopK) heaps keyed by
/// global iteration index; the master k-way-merges the heaps, which
/// reproduces the sequential emission exactly (a globally-top-k row is
/// top-k within its chunk, and the global sequence numbers make the
/// merge deterministic).
fn emit_topk_fanout(
    cp: &CompiledProgram,
    sl: &ScanLoop,
    master: &mut VecState,
    threads: usize,
    policy: Policy,
    affinity: bool,
) -> Result<()> {
    let spec = sl.emit.clone().expect("emit_parallel_safe implies emit");
    // The distinct domain (group-by emit) iterates one representative
    // row per distinct value; plain annotated scans iterate table rows.
    let firsts: Option<Vec<u32>> = sl
        .distinct
        .map(|field| DistinctIndex::build(&sl.table, field).firsts);
    if firsts.is_some() {
        master.stats.index_builds += 1;
    }
    let n_items = firsts.as_ref().map_or(sl.table.len(), |f| f.len());
    // Equality-filter keys are scope-constant: evaluate once on the
    // master's complete pre-loop state. Distinct iteration ignores the
    // filter (interpreter contract: the distinct branch takes
    // precedence), so the key is only evaluated for plain scans.
    let filter = match (&sl.filter, sl.distinct) {
        (Some((fid, prog)), None) => Some((*fid, master.eval_value(cp, prog)?)),
        _ => None,
    };
    if !crate::opt::should_fan_out(n_items, threads) {
        // Too few emitted rows to amortize worker spin-up: run on the
        // master — through the same chunk driver, reusing the distinct
        // index already built for the gate.
        master.note_idiom("opt.small_scan_seq");
        master.begin_topk(TopKSet::new(spec.clone(), cp.result_schemas.len()));
        let r = match &firsts {
            Some(fs) => master.emit_scan_chunk(
                cp,
                sl,
                filter.as_ref(),
                EmitChunk::Firsts { firsts: fs, base: 0 },
            ),
            None => {
                master.emit_scan_chunk(cp, sl, filter.as_ref(), EmitChunk::Rows {
                    lo: 0,
                    hi: n_items,
                })
            }
        };
        let frame = master.take_topk().expect("frame installed above");
        r?;
        if frame.heap_mode() {
            master.note_idiom("vec.topk");
        }
        for (slot, rows) in frame.finish() {
            for row in rows {
                master.results[slot].push(row);
            }
        }
        return Ok(());
    }
    let filter = &filter;
    let firsts = &firsts;
    let units = n_items.div_ceil(BATCH);
    let workers = threads.min(units);
    // Workers read the master's complete accumulator state (the emit
    // body reads the accumulators the preceding loops filled, and the
    // master has executed everything before this statement). The store
    // is moved into an `Arc` and shared read-only — no per-worker
    // copies — then restored onto the master once the pool has joined.
    let shared = Arc::new(std::mem::take(&mut master.arrays));
    let spec_ref = &spec;
    let collected: Mutex<Vec<TopKSet>> = Mutex::new(Vec::new());
    let states = {
        let shared = &shared;
        let collected = &collected;
        morsel_dispatch(
            MorselJob {
                cp,
                scalars: &master.scalars,
                params: &master.params,
                units,
                workers,
                policy,
                affinity,
            },
            |st| {
                st.set_shared_arrays(shared.clone());
                st.begin_topk(TopKSet::new(spec_ref.clone(), cp.result_schemas.len()));
            },
            |st, _ctx, c| {
                let (lo, hi) = (c.lo * BATCH, (c.hi * BATCH).min(n_items));
                match firsts {
                    Some(fs) => st.emit_scan_chunk(
                        cp,
                        sl,
                        filter.as_ref(),
                        EmitChunk::Firsts {
                            firsts: &fs[lo..hi],
                            base: lo,
                        },
                    ),
                    None => {
                        st.emit_scan_chunk(cp, sl, filter.as_ref(), EmitChunk::Rows { lo, hi })
                    }
                }
            },
            |st, _ctx| {
                if let Some(frame) = st.take_topk() {
                    collected.lock().expect("no poisoned lock").push(frame);
                }
                Ok(())
            },
        )
    };
    // No `absorb` here: workers never touch accumulators or results (the
    // frames in `collected` carry the retained rows); only the traversal
    // stats come back. Dropping the worker states releases their `Arc`
    // handles, so the store can be restored onto the master without a
    // copy — on the error path too, before propagating.
    let stats_only: Result<bool> = states.map(|(sts, engaged)| {
        for st in sts {
            master.stats.rows_visited += st.stats.rows_visited;
        }
        engaged
    });
    master.arrays = Arc::try_unwrap(shared).unwrap_or_else(|a| (*a).clone());
    let engaged = stats_only?;
    let mut merged = TopKSet::new(spec, cp.result_schemas.len());
    for frame in collected.lock().expect("no poisoned lock").drain(..) {
        merged.merge(frame);
    }
    for (slot, rows) in merged.finish() {
        for row in rows {
            master.results[slot].push(row);
        }
    }
    master.note_idiom("vec.topk");
    master.note_idiom("vec.morsel");
    master.note_idiom(&format!("sched.{}", policy.name()));
    if engaged {
        master.note_idiom("sched.affinity");
    }
    Ok(())
}

/// Morsel-driven fan-out of an **unbounded** distinct-emission scan —
/// the group-by emit half when no ORDER BY/LIMIT bounds the output, so
/// there is no heap to merge: every emitted row is kept. Workers pull
/// [`BATCH`]-sized slices of the distinct-firsts list, run the body over
/// a read-only `Arc` snapshot of the master's complete accumulator
/// state, and drain the rows appended during each chunk into a
/// `(chunk_start, rows)` record; the master sorts the records by chunk
/// start and concatenates — per-chunk runs in chunk order *are* the
/// sequential emission order, so even order-sensitive consumers see
/// identical output. Tags `vec.emit_par`.
fn emit_unbounded_fanout(
    cp: &CompiledProgram,
    sl: &ScanLoop,
    master: &mut VecState,
    threads: usize,
    policy: Policy,
    affinity: bool,
) -> Result<()> {
    let field = sl.distinct.expect("distinct_emit_parallel_safe implies distinct");
    let firsts = DistinctIndex::build(&sl.table, field).firsts;
    master.stats.index_builds += 1;
    if !crate::opt::should_fan_out(firsts.len(), threads) {
        // Too few distinct groups to amortize worker spin-up: emit on
        // the master, reusing the index already built for the gate.
        master.note_idiom("opt.small_scan_seq");
        return master.run_distinct_rows(cp, sl, &firsts);
    }
    let units = firsts.len().div_ceil(BATCH);
    let workers = threads.min(units);
    // Share the master's complete accumulator state read-only (one
    // `Arc`, no per-worker copies), exactly like the top-k fan-out.
    let shared = Arc::new(std::mem::take(&mut master.arrays));
    let firsts = &firsts;
    // Per-chunk emission runs, keyed by the chunk's position in the
    // firsts list so the master can restore sequential order.
    type ChunkRun = (usize, Vec<crate::ir::Multiset>);
    let collected: Mutex<Vec<ChunkRun>> = Mutex::new(Vec::new());
    let states = {
        let shared = &shared;
        let collected = &collected;
        morsel_dispatch(
            MorselJob {
                cp,
                scalars: &master.scalars,
                params: &master.params,
                units,
                workers,
                policy,
                affinity,
            },
            |st| st.set_shared_arrays(shared.clone()),
            |st, _ctx, c| {
                let (lo, hi) = (c.lo * BATCH, (c.hi * BATCH).min(firsts.len()));
                st.run_distinct_rows(cp, sl, &firsts[lo..hi])?;
                // Drain the rows this chunk appended (the worker's
                // result slots are empty between chunks, so everything
                // present belongs to this chunk).
                let fresh: Vec<crate::ir::Multiset> = cp
                    .result_schemas
                    .iter()
                    .map(|s| crate::ir::Multiset::new(s.clone()))
                    .collect();
                let run = std::mem::replace(&mut st.results, fresh);
                collected.lock().expect("no poisoned lock").push((lo, run));
                Ok(())
            },
            |_st, _ctx| Ok(()),
        )
    };
    // Workers never touch accumulators (reads go through the shared
    // snapshot; the eligibility analysis bans writes) and their result
    // slots were drained per chunk — only traversal stats come back.
    // Restore the store before propagating any error.
    let stats_only: Result<bool> = states.map(|(sts, engaged)| {
        for st in sts {
            master.stats.rows_visited += st.stats.rows_visited;
        }
        engaged
    });
    master.arrays = Arc::try_unwrap(shared).unwrap_or_else(|a| (*a).clone());
    let engaged = stats_only?;
    let mut runs = collected.into_inner().expect("no poisoned lock");
    runs.sort_unstable_by_key(|(lo, _)| *lo);
    for (_, run) in runs {
        for (slot, m) in run.into_iter().enumerate() {
            for row in m.into_rows() {
                master.results[slot].push(row);
            }
        }
    }
    master.note_idiom("vec.emit_par");
    master.note_idiom("vec.morsel");
    master.note_idiom(&format!("sched.{}", policy.name()));
    if engaged {
        master.note_idiom("sched.affinity");
    }
    Ok(())
}

/// Interpreter-based fallback for programs the vectorized tier does not
/// support (value partitions, distinct-value domains, ...). Each worker
/// runs a private `Interp` over a static share of the iterations.
pub(crate) fn run_parallel_interp(
    program: &Program,
    catalog: &StorageCatalog,
    max_threads: usize,
) -> Result<Output> {
    let threads = clamp_threads(max_threads);
    let mut master = Interp::new(program, catalog);
    for s in &program.body {
        match s {
            // An ordered/bounded emission must stay whole — the unordered
            // worker merge would drop the contract — so annotated foralls
            // run sequentially on the master (which sorts/bounds them).
            Stmt::Loop(l) if l.kind == LoopKind::Forall && l.emit.is_none() => {
                if let Domain::Range { lo, hi } = &l.domain {
                    // Evaluate bounds in the master environment.
                    let lo = super::eval::eval(lo, &master.env, &master.arrays, program)?
                        .as_int()
                        .context("forall lo")?;
                    let hi = super::eval::eval(hi, &master.env, &master.arrays, program)?
                        .as_int()
                        .context("forall hi")?;
                    if hi < lo {
                        continue; // empty range: spawning would div_ceil(0)
                    }
                    let iters: Vec<i64> = (lo..=hi).collect();

                    // Fan out: each worker runs with a PRIVATE, empty
                    // accumulator store. This is sound for the programs
                    // the parallelizing transforms generate: privatized
                    // bodies only touch their own k-slice of each array
                    // and never read pre-loop accumulator state.
                    let chunk = iters.len().div_ceil(threads).max(1);
                    let chunks: Vec<Vec<i64>> =
                        iters.chunks(chunk).map(|c| c.to_vec()).collect();
                    type WorkerOut =
                        (ArrayStore, BTreeMap<String, crate::ir::Multiset>, ExecStats, Vec<String>);
                    let results: Vec<Result<WorkerOut>> = std::thread::scope(|scope| {
                        let handles: Vec<_> = chunks
                            .iter()
                            .map(|chunk| {
                                let body = &l.body;
                                let var = &l.var;
                                scope.spawn(move || {
                                    let mut worker = Interp::new(program, catalog);
                                    for &k in chunk {
                                        worker.env.push_var(var, Value::Int(k));
                                        let r = worker.run_body(body);
                                        worker.env.pop_var();
                                        r?;
                                    }
                                    Ok((
                                        worker.arrays,
                                        worker.results,
                                        worker.stats,
                                        worker.prints,
                                    ))
                                })
                            })
                            .collect();
                        handles
                            .into_iter()
                            .map(|h| h.join().expect("forall worker panicked"))
                            .collect()
                    });

                    // Merge worker stores into the master: privatized
                    // slices are disjoint, and any residual overlap is a
                    // commutative Add (merge_add handles both). Prints
                    // append in chunk order, matching the compiled path.
                    for r in results {
                        let (arrays, results, stats, prints) = r?;
                        master.arrays.merge_add(arrays);
                        for (name, m) in results {
                            if let Some(dst) = master.results.get_mut(&name) {
                                for row in m.into_rows() {
                                    dst.push(row);
                                }
                            }
                        }
                        master.stats.rows_visited += stats.rows_visited;
                        master.stats.index_builds += stats.index_builds;
                        master.prints.extend(prints);
                    }
                    continue;
                }
                // Non-range forall: run sequentially (rare).
                master.run_body(std::slice::from_ref(s))?;
            }
            other => master.run_body(std::slice::from_ref(other))?,
        }
    }
    Ok(master.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Expr;
    use crate::sql::compile_sql;
    use crate::transform::{DirectPartition, Pass, PassCtx};
    use crate::workload::{access_log, AccessLogSpec};

    fn setup(rows: usize) -> (Program, StorageCatalog) {
        let m = access_log(&AccessLogSpec {
            rows,
            urls: 200,
            skew: 1.1,
            seed: 3,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let mut p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        DirectPartition
            .run(&mut p, &PassCtx::new().with_processors(8))
            .unwrap();
        (p, c)
    }

    /// Plain SQL group-by (no forall): the morsel scan path's bread and
    /// butter.
    fn scan_setup(rows: usize) -> (Program, StorageCatalog) {
        let m = access_log(&AccessLogSpec {
            rows,
            urls: 200,
            skew: 1.1,
            seed: 5,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        (p, c)
    }

    #[test]
    fn parallel_forall_matches_sequential() {
        let (p, c) = setup(20_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        for threads in [1, 2, 4, 8] {
            let par = run_parallel(&p, &c, threads).unwrap();
            assert!(
                par.result().unwrap().bag_eq(seq.result().unwrap()),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn morsel_scan_matches_sequential_and_tags_policy() {
        let (p, c) = scan_setup(10_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        for policy in Policy::ALL {
            let par = run_parallel_compiled_with_policy(
                &compile_program(&p, &c).unwrap(),
                4,
                policy,
            )
            .unwrap();
            assert!(
                par.result().unwrap().bag_eq(seq.result().unwrap()),
                "{policy:?}"
            );
            assert!(
                par.stats.idioms.contains(&"vec.morsel".to_string()),
                "{policy:?}: {:?}",
                par.stats.idioms
            );
            let tag = format!("sched.{}", policy.name());
            assert!(
                par.stats.idioms.contains(&tag),
                "{policy:?}: {:?}",
                par.stats.idioms
            );
            // The fused count kernel fires per-morsel inside the workers.
            assert!(
                par.stats.idioms.contains(&"vec.count".to_string()),
                "{policy:?}: {:?}",
                par.stats.idioms
            );
        }
    }

    #[test]
    fn small_scans_stay_sequential() {
        // Below one BATCH there is nothing to fan out: no morsel tag.
        let (p, c) = scan_setup(500);
        let seq = super::super::local::run(&p, &c).unwrap();
        let par = run_parallel(&p, &c, 8).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
        assert!(!par.stats.idioms.contains(&"vec.morsel".to_string()));
    }

    #[test]
    fn interp_fallback_matches_sequential() {
        let (p, c) = setup(5_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        let par = run_parallel_interp(&p, &c, 4).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
    }

    #[test]
    fn parallel_handles_programs_without_forall() {
        let m = access_log(&AccessLogSpec {
            rows: 100,
            urls: 10,
            skew: 1.0,
            seed: 1,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let p = compile_sql("SELECT url FROM access", &c.schemas()).unwrap();
        let out = run_parallel(&p, &c, 4).unwrap();
        assert_eq!(out.result().unwrap().len(), 100);
    }

    #[test]
    fn max_threads_clamp_is_uniform_across_paths() {
        // One clamp (`clamp_threads`) governs every arm: 0 behaves like 1
        // and oversubscription is capped by the work itself.
        let (p, c) = scan_setup(3_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        for threads in [0, 1, 64] {
            let par = run_parallel(&p, &c, threads).unwrap();
            assert!(
                par.result().unwrap().bag_eq(seq.result().unwrap()),
                "scan path, threads={threads}"
            );
        }
        let (fp, fc) = setup(3_000);
        let fseq = super::super::local::run(&fp, &fc).unwrap();
        for threads in [0, 1, 64] {
            let par = run_parallel(&fp, &fc, threads).unwrap();
            assert!(
                par.result().unwrap().bag_eq(fseq.result().unwrap()),
                "forall path, threads={threads}"
            );
        }
        let (jc, join, agg) = join_setup(5_000, 100);
        for p in [&join, &agg] {
            let jseq = super::super::local::run(p, &jc).unwrap();
            for threads in [0, 1, 64] {
                let par = run_parallel(p, &jc, threads).unwrap();
                assert!(
                    par.result().unwrap().bag_eq(jseq.result().unwrap()),
                    "join path, threads={threads}"
                );
            }
        }
    }

    #[test]
    fn zero_max_threads_does_not_panic() {
        let (p, c) = setup(2_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        // Both drivers clamp to one worker.
        let par = run_parallel(&p, &c, 0).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
        let par = run_parallel_interp(&p, &c, 0).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
    }

    #[test]
    fn empty_forall_range_does_not_panic() {
        // forall k = 1..=0 over the accumulation: zero iterations (the
        // emit loop still runs, so compare against the interpreter rather
        // than asserting emptiness).
        let (mut p, c) = setup(500);
        if let Stmt::Loop(forall) = &mut p.body[0] {
            if let Domain::Range { hi, .. } = &mut forall.domain {
                *hi = Expr::int(0);
            }
        }
        let seq = super::super::local::run(&p, &c).unwrap();
        let out = run_parallel(&p, &c, 4).unwrap();
        assert!(out.result().unwrap().bag_eq(seq.result().unwrap()));
        let out = run_parallel_interp(&p, &c, 4).unwrap();
        assert!(out.result().unwrap().bag_eq(seq.result().unwrap()));
    }

    fn join_setup(arows: usize, brows: usize) -> (StorageCatalog, Program, Program) {
        use crate::ir::{DataType, Multiset, Schema, Value};
        let mut rng = crate::util::Rng::new(21);
        let mut a = Multiset::new(Schema::new(vec![
            ("b_id", DataType::Int),
            ("g", DataType::Str),
        ]));
        for _ in 0..arows {
            a.push(vec![
                Value::Int(rng.range(0, brows as i64 * 2)),
                Value::str(format!("g{}", rng.below(16))),
            ]);
        }
        let mut b = Multiset::new(Schema::new(vec![("id", DataType::Int)]));
        for i in 0..brows {
            b.push(vec![Value::Int(i as i64)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("A", &a).unwrap();
        c.insert_multiset("B", &b).unwrap();
        let join = compile_sql(
            "SELECT A.g, B.id FROM A JOIN B ON A.b_id = B.id",
            &c.schemas(),
        )
        .unwrap();
        let agg = compile_sql(
            "SELECT g, COUNT(g) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
            &c.schemas(),
        )
        .unwrap();
        (c, join, agg)
    }

    #[test]
    fn parallel_hash_join_matches_sequential() {
        let (c, join, agg) = join_setup(20_000, 500);
        for p in [&join, &agg] {
            let seq = super::super::local::run(p, &c).unwrap();
            for threads in [1, 2, 4, 8] {
                let par = run_parallel(p, &c, threads).unwrap();
                assert!(
                    par.result().unwrap().bag_eq(seq.result().unwrap()),
                    "threads={threads}"
                );
            }
        }
    }

    #[test]
    fn parallel_join_tags_hash_join_idiom() {
        let (c, join, _) = join_setup(10_000, 200);
        let par = run_parallel(&join, &c, 4).unwrap();
        assert!(
            par.stats.idioms.contains(&"vec.hash_join".to_string()),
            "{:?}",
            par.stats.idioms
        );
        assert!(
            par.stats.idioms.contains(&"vec.morsel".to_string()),
            "{:?}",
            par.stats.idioms
        );
    }

    #[test]
    fn parallel_join_matches_under_every_policy() {
        let (c, join, agg) = join_setup(15_000, 300);
        for p in [&join, &agg] {
            let seq = super::super::local::run(p, &c).unwrap();
            for policy in Policy::ALL {
                let par = run_parallel_compiled_with_policy(
                    &compile_program(p, &c).unwrap(),
                    4,
                    policy,
                )
                .unwrap();
                assert!(
                    par.result().unwrap().bag_eq(seq.result().unwrap()),
                    "{policy:?}"
                );
            }
        }
    }

    #[test]
    fn tiny_join_runs_sequentially_and_matches() {
        // Below the fan-out threshold the join stays on the master state.
        let (c, join, agg) = join_setup(50, 10);
        for p in [&join, &agg] {
            let seq = super::super::local::run(p, &c).unwrap();
            let par = run_parallel(p, &c, 8).unwrap();
            assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
        }
    }

    #[test]
    fn nonzero_init_accumulators_keep_forall_sequential() {
        // Worker merges are add-based: a non-zero array init would be
        // counted once per worker, so such forall loops must not fan out.
        use crate::ir::{AccumOp, ArrayDecl, BinOp, DataType, Loop, Multiset, Schema};
        let mut c = StorageCatalog::new();
        let m = Multiset::new(Schema::new(vec![("x", DataType::Int)]));
        c.insert_multiset("t", &m).unwrap();
        let mut p = Program::new("init5")
            .with_relation("t", c.schemas()["t"].clone())
            .with_array(
                "acc",
                ArrayDecl {
                    dims: 1,
                    dtype: DataType::Int,
                    init: Value::Int(5),
                },
            )
            .with_result(
                "R",
                Schema::new(vec![("a", DataType::Int), ("b", DataType::Int)]),
            );
        p.body = vec![
            Stmt::Loop(Loop::forall_range(
                "k",
                Expr::int(1),
                Expr::int(8),
                vec![Stmt::accum(
                    "acc",
                    vec![Expr::bin(BinOp::Mod, Expr::var("k"), Expr::int(2))],
                    AccumOp::Add,
                    Expr::int(1),
                )],
            )),
            Stmt::result_union(
                "R",
                vec![
                    Expr::array("acc", vec![Expr::int(0)]),
                    Expr::array("acc", vec![Expr::int(1)]),
                ],
            ),
        ];
        let seq = super::super::local::run(&p, &c).unwrap();
        let par = run_parallel(&p, &c, 4).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
        assert!(!par.stats.idioms.contains(&"vec.morsel".to_string()));
    }

    #[test]
    fn ineligible_scan_bodies_stay_sequential() {
        // A scalar-assigning scan body must not fan out: the final scalar
        // is order-dependent, so it runs on the master and matches the
        // interpreter exactly.
        use crate::ir::{IndexSet, Loop};
        let m = access_log(&AccessLogSpec {
            rows: 3_000,
            urls: 50,
            skew: 1.0,
            seed: 9,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let mut p = Program::new("assign")
            .with_relation("access", c.schemas()["access"].clone())
            .with_scalar("last", Value::str(""));
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("access"),
            vec![Stmt::assign("last", Expr::field("i", "url"))],
        ))];
        let seq = super::super::local::run(&p, &c).unwrap();
        let par = run_parallel(&p, &c, 8).unwrap();
        assert_eq!(par.scalars, seq.scalars);
        assert!(!par.stats.idioms.contains(&"vec.morsel".to_string()));
    }

    /// Group-by with enough distinct groups (> the spin-up gate) that
    /// the top-k emit fan-out engages.
    fn topk_setup() -> (Program, StorageCatalog) {
        use crate::ir::{DataType, Multiset, Schema, Value};
        let mut m = Multiset::new(Schema::new(vec![("k", DataType::Str)]));
        for i in 0..6000usize {
            for _ in 0..(1 + i % 7) {
                m.push(vec![Value::str(format!("key{i:04}"))]);
            }
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("t", &m).unwrap();
        let p = compile_sql(
            "SELECT k, COUNT(k) AS n FROM t GROUP BY k ORDER BY n DESC LIMIT 25",
            &c.schemas(),
        )
        .unwrap();
        (p, c)
    }

    #[test]
    fn parallel_topk_emission_matches_sequential_rows_exactly() {
        // The emit half of the group-by fans out: per-worker bounded
        // heaps + k-way merge must reproduce the interpreter's stable
        // sort prefix row-for-row (ties bound to emission order), under
        // every scheduling policy and several thread counts.
        let (p, c) = topk_setup();
        let reference = super::super::local::run(&p, &c).unwrap();
        assert_eq!(reference.result().unwrap().len(), 25);
        let cp = compile_program(&p, &c).unwrap();
        for policy in Policy::ALL {
            for threads in [2, 4, 8] {
                let par = run_parallel_compiled_with_policy(&cp, threads, policy).unwrap();
                assert_eq!(
                    par.result().unwrap().rows(),
                    reference.result().unwrap().rows(),
                    "{policy:?} threads={threads}"
                );
                for tag in ["vec.topk", "vec.morsel"] {
                    assert!(
                        par.stats.idioms.contains(&tag.to_string()),
                        "{policy:?}: missing {tag}: {:?}",
                        par.stats.idioms
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_unbounded_emission_matches_sequential_rows_exactly() {
        // Group-by with no ORDER BY/LIMIT and enough distinct groups to
        // clear the spin-up gate: the unbounded emit fan-out's per-chunk
        // row runs, concatenated in chunk order, must reproduce the
        // interpreter's emission row-for-row under every policy.
        use crate::ir::{DataType, Multiset, Schema, Value};
        let mut m = Multiset::new(Schema::new(vec![("k", DataType::Str)]));
        for i in 0..6000usize {
            for _ in 0..(1 + i % 7) {
                m.push(vec![Value::str(format!("key{i:04}"))]);
            }
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("t", &m).unwrap();
        let p = compile_sql("SELECT k, COUNT(k) AS n FROM t GROUP BY k", &c.schemas())
            .unwrap();
        let reference = super::super::local::run(&p, &c).unwrap();
        assert_eq!(reference.result().unwrap().len(), 6000);
        let cp = compile_program(&p, &c).unwrap();
        for policy in Policy::ALL {
            for threads in [2, 4, 8] {
                let par = run_parallel_compiled_with_policy(&cp, threads, policy).unwrap();
                assert_eq!(
                    par.result().unwrap().rows(),
                    reference.result().unwrap().rows(),
                    "{policy:?} threads={threads}"
                );
                for tag in ["vec.emit_par", "vec.morsel"] {
                    assert!(
                        par.stats.idioms.contains(&tag.to_string()),
                        "{policy:?}: missing {tag}: {:?}",
                        par.stats.idioms
                    );
                }
            }
        }
    }

    #[test]
    fn bound_params_reach_morsel_workers() {
        // Compile a parameterized group-by once, execute with two
        // different bindings on the full pool: each run must match an
        // interpreter run of the program with that binding installed —
        // proving workers see the per-execution values, not the
        // compile-time defaults.
        use crate::workload::access_log_wide;
        let m = access_log_wide(&AccessLogSpec {
            rows: 60_000,
            urls: 200,
            skew: 1.1,
            seed: 11,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access WHERE bytes > ? GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let cp = compile_program(&p, &c).unwrap();
        assert_eq!(cp.param_names, vec!["$1".to_string()]);
        for bound in [500i64, 100_000] {
            let mut bound_p = p.clone();
            bound_p.params.insert("$1".into(), Value::Int(bound));
            let seq = super::super::local::run(&bound_p, &c).unwrap();
            let par =
                run_parallel_compiled_with_params(&cp, vec![Value::Int(bound)], 8).unwrap();
            assert!(
                par.result().unwrap().bag_eq(seq.result().unwrap()),
                "bound={bound}"
            );
        }
        // Arity mismatches are rejected, not silently defaulted.
        assert!(run_parallel_compiled_with_params(&cp, vec![], 8).is_err());
    }

    #[test]
    fn small_topk_emission_stays_sequential_and_matches() {
        // Few groups: the spin-up gate keeps the emit loop on the master
        // (and says so), still row-identical to the interpreter.
        use crate::ir::{DataType, Multiset, Schema, Value};
        let mut m = Multiset::new(Schema::new(vec![("k", DataType::Str)]));
        for i in 0..5000usize {
            m.push(vec![Value::str(format!("key{}", i % 40))]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("t", &m).unwrap();
        let p = compile_sql(
            "SELECT k, COUNT(k) AS n FROM t GROUP BY k ORDER BY n DESC LIMIT 5",
            &c.schemas(),
        )
        .unwrap();
        let reference = super::super::local::run(&p, &c).unwrap();
        let par = run_parallel(&p, &c, 8).unwrap();
        assert_eq!(
            par.result().unwrap().rows(),
            reference.result().unwrap().rows()
        );
        assert!(
            par.stats.idioms.contains(&"opt.small_scan_seq".to_string()),
            "{:?}",
            par.stats.idioms
        );
        // The sequential emission still runs the bounded-heap kernel.
        assert!(
            par.stats.idioms.contains(&"vec.topk".to_string()),
            "{:?}",
            par.stats.idioms
        );
    }

    #[test]
    fn spinup_gate_holds_small_tables_and_releases_big_ones() {
        // The recalibrated PARALLEL_SPINUP_ROWS: a 100-row scan stays
        // sequential (and says so), a 100k-row scan fans out.
        let (p, c) = scan_setup(100);
        let seq = super::super::local::run(&p, &c).unwrap();
        let par = run_parallel(&p, &c, 8).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
        assert!(
            par.stats.idioms.contains(&"opt.small_scan_seq".to_string()),
            "{:?}",
            par.stats.idioms
        );
        assert!(!par.stats.idioms.contains(&"vec.morsel".to_string()));

        let (p, c) = scan_setup(100_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        let par = run_parallel(&p, &c, 8).unwrap();
        assert!(par.result().unwrap().bag_eq(seq.result().unwrap()));
        assert!(
            par.stats.idioms.contains(&"vec.morsel".to_string()),
            "{:?}",
            par.stats.idioms
        );
        // The 100k-row accumulation scan fans out; the 200-group emit
        // half stays under its own gate (and says so), so the unbounded
        // emit fan-out must not have engaged.
        assert!(!par.stats.idioms.contains(&"vec.emit_par".to_string()));
    }

    #[test]
    fn affinity_toggle_matches_and_tags() {
        // Affinity on/off must be semantically invisible; with a
        // fixed-chunk policy every worker pulls multiple chunks from its
        // home region, so the adjacency signal deterministically engages
        // and the fan-out tags `sched.affinity` (and only then).
        let (p, c) = scan_setup(100_000);
        let seq = super::super::local::run(&p, &c).unwrap();
        let cp = compile_program(&p, &c).unwrap();
        let on =
            run_parallel_compiled_with_opts(&cp, 4, Policy::FixedChunk(4), true).unwrap();
        assert!(on.result().unwrap().bag_eq(seq.result().unwrap()));
        assert!(
            on.stats.idioms.contains(&"sched.affinity".to_string()),
            "{:?}",
            on.stats.idioms
        );
        let off =
            run_parallel_compiled_with_opts(&cp, 4, Policy::FixedChunk(4), false).unwrap();
        assert!(off.result().unwrap().bag_eq(seq.result().unwrap()));
        assert!(
            !off.stats.idioms.contains(&"sched.affinity".to_string()),
            "{:?}",
            off.stats.idioms
        );
    }

    #[test]
    fn parallel_is_faster_on_big_input() {
        // Not a strict assertion (CI noise), but sanity-log the ratio.
        let (p, c) = setup(200_000);
        let t0 = std::time::Instant::now();
        let _ = super::super::local::run(&p, &c).unwrap();
        let seq_t = t0.elapsed();
        let t0 = std::time::Instant::now();
        let _ = run_parallel(&p, &c, 8).unwrap();
        let par_t = t0.elapsed();
        eprintln!("seq {seq_t:?} vs par {par_t:?}");
    }
}
