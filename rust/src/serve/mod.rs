//! Concurrent query serving: prepared plans over a shared worker pool.
//!
//! The serving layer is the multi-query face of the compiler: where
//! `Engine::sql` compiles and runs one query on the calling thread (with
//! a private scoped thread pool for big scans), [`Server`] keeps ONE
//! long-lived morsel worker pool and multiplexes every admitted query
//! over it:
//!
//! 1. **prepare** — parse + compile through the engine's plan cache
//!    (`Engine::plan_cached`): repeat preparations of the same
//!    normalized statement reuse the compiled plan, and `?`/`$n`
//!    placeholders stay late-bound IR parameter slots;
//! 2. **execute** — bind a parameter vector and run. Admission control
//!    (bounded in-flight, FIFO overflow) throttles the pool; eligible
//!    scans fan out as per-query morsel phases on the shared
//!    [`MultiScheduler`](crate::sched::MultiScheduler), so chunks of
//!    concurrent queries interleave fairly instead of queueing
//!    query-by-query;
//! 3. **re-optimize on binding drift** — each prepared statement
//!    remembers the histogram selectivity of its first binding; a later
//!    binding whose estimate moves by [`REBIND_RATIO`]× or more in either
//!    direction triggers a one-off re-plan with the literals inlined
//!    (`opt.rebind`), giving the optimizer the constants it never saw.
//!
//! Execution stats carry the serving tags: `serve.admit` on every
//! pool-served execution, `serve.queued` when admission had to wait,
//! `serve.cache_hit` when the prepared plan came from the plan cache,
//! `sched.multi` when morsel phases ran on the shared pool, and
//! `opt.rebind` on a re-optimized execution.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::compiler::{Compiled, Engine};
use crate::exec::compile::{scan_parallel_safe, CStmt, CompiledProgram};
use crate::exec::parallel::zero_init_accums;
use crate::exec::vector::{VecState, BATCH};
use crate::exec::{self, Output};
use crate::ir::{BinOp, Expr, Value};
use crate::opt::Estimator;
use crate::sched::Policy;
use crate::sql::{self, SqlBinOp, SqlExpr};
use crate::storage::StorageCatalog;

pub mod pool;

pub use pool::SharedPool;

/// Re-optimization trigger: a binding whose estimated selectivity moves
/// at least this factor away from the prepared statement's baseline (in
/// either direction) gets a fresh plan with the literal inlined.
/// Deliberately coarse — ordinary binding drift must NOT recompile (the
/// whole point of preparing is compiling once).
pub const REBIND_RATIO: f64 = 8.0;

/// One `column cmp ?` conjunct of a prepared statement's WHERE clause:
/// everything the selectivity estimator needs to price a concrete
/// binding at execute time.
struct RebindConjunct {
    relation: String,
    field: String,
    op: BinOp,
    /// 1-based parameter index the conjunct compares against.
    param: usize,
}

/// A prepared statement: the cached compiled plan plus everything one
/// execution needs without re-entering the compiler. Shareable across
/// client threads (`Arc<Prepared>`); every execution binds its own
/// parameter vector.
pub struct Prepared {
    sql: String,
    compiled: Arc<Compiled>,
    /// Vectorized form, when the program compiles to the batch tier.
    /// Executions fan eligible scans out on the server's shared pool.
    cp: Option<Arc<CompiledProgram>>,
    /// Catalog snapshot for the interpreter fallback (the compiled
    /// program holds its table `Arc`s directly).
    catalog: StorageCatalog,
    cache_hit: bool,
    n_params: usize,
    rebind: Vec<RebindConjunct>,
    /// Estimated selectivity of the first executed binding; later
    /// bindings compare against this (see [`REBIND_RATIO`]).
    baseline: Mutex<Option<f64>>,
}

impl Prepared {
    /// Did `prepare` get this plan from the engine's plan cache?
    pub fn cache_hit(&self) -> bool {
        self.cache_hit
    }

    /// Number of parameters the statement declares.
    pub fn param_count(&self) -> usize {
        self.n_params
    }
}

/// The in-process query server: one engine (compiler + catalog + plan
/// cache) behind a mutex, one shared morsel worker pool serving every
/// admitted execution. No network — embedders call `prepare`/`execute`
/// directly from their own threads.
pub struct Server {
    engine: Mutex<Engine>,
    pool: SharedPool,
}

impl Server {
    /// Wrap an engine with a `workers`-wide shared pool admitting at
    /// most `max_inflight` concurrently executing queries.
    pub fn new(engine: Engine, workers: usize, max_inflight: usize) -> Self {
        Server {
            engine: Mutex::new(engine),
            pool: SharedPool::new(workers, max_inflight),
        }
    }

    /// Prepare a statement: compile through the plan cache, pre-compile
    /// the vectorized form, and record the `column cmp ?` conjuncts the
    /// rebind check prices at execute time.
    pub fn prepare(&self, query: &str) -> Result<Prepared> {
        let select = sql::parse(query)?;
        let mut eng = self.engine.lock().expect("engine lock");
        let (compiled, cache_hit) = eng.plan_cached(query)?;
        let cp = exec::compile_program(&compiled.program, &eng.catalog).map(Arc::new);
        let catalog = eng.catalog.clone();
        drop(eng);
        let n_params = compiled
            .program
            .params
            .keys()
            .filter_map(|k| parse_slot(k))
            .max()
            .unwrap_or(0);
        Ok(Prepared {
            sql: query.to_string(),
            compiled,
            cp,
            catalog,
            cache_hit,
            n_params,
            rebind: rebind_conjuncts(&select),
            baseline: Mutex::new(None),
        })
    }

    /// Execute a prepared statement under the given binding (`params[0]`
    /// is `$1`). Admission-controlled; eligible scans run as morsel
    /// phases on the shared pool.
    pub fn execute(&self, prepared: &Prepared, params: &[Value]) -> Result<Output> {
        if params.len() != prepared.n_params {
            bail!(
                "binding has {} values but the statement declares {} parameters",
                params.len(),
                prepared.n_params
            );
        }
        let (qid, waited) = self.pool.admit();
        let run = self.execute_admitted(prepared, params, qid);
        self.pool.release(qid);
        let (mut out, pooled, rebound) = run?;
        note_tag(&mut out, "serve.admit");
        if waited {
            note_tag(&mut out, "serve.queued");
        }
        if prepared.cache_hit {
            note_tag(&mut out, "serve.cache_hit");
        }
        if pooled {
            note_tag(&mut out, "sched.multi");
        }
        if rebound {
            note_tag(&mut out, "opt.rebind");
        }
        Ok(out)
    }

    /// Execution body between `admit` and `release`. Returns the output
    /// plus whether pool phases ran and whether the binding was
    /// re-optimized.
    fn execute_admitted(
        &self,
        prepared: &Prepared,
        params: &[Value],
        qid: u64,
    ) -> Result<(Output, bool, bool)> {
        if self.should_rebind(prepared, params) {
            if let Some(out) = self.execute_rebound(prepared, params)? {
                return Ok((out, false, true));
            }
        }
        match &prepared.cp {
            Some(cp) => {
                let slot_params = slot_order(&cp.param_names, params)?;
                let (out, pooled) = self.run_pooled(qid, cp, slot_params)?;
                Ok((out, pooled, false))
            }
            None => {
                // Interpreter fallback: install the binding into the
                // program's parameter table and run the reference tier
                // against the prepared catalog snapshot.
                let mut p = prepared.compiled.program.clone();
                let names: Vec<String> = p.params.keys().cloned().collect();
                for name in names {
                    let idx = parse_slot(&name)
                        .with_context(|| format!("unrecognized parameter slot `{name}`"))?;
                    let v = params
                        .get(idx - 1)
                        .cloned()
                        .with_context(|| format!("no binding for parameter `{name}`"))?;
                    p.params.insert(name, v);
                }
                let out = exec::run(&p, &prepared.catalog)?;
                Ok((out, false, false))
            }
        }
    }

    /// Price the binding with the statistics estimator. The first
    /// executed binding sets the baseline; later bindings trigger a
    /// rebind when their estimate drifts [`REBIND_RATIO`]× away.
    fn should_rebind(&self, prepared: &Prepared, params: &[Value]) -> bool {
        if prepared.rebind.is_empty() {
            return false;
        }
        let est = Estimator::new(&prepared.catalog);
        let mut sel = 1.0;
        for c in &prepared.rebind {
            let Some(v) = params.get(c.param - 1) else {
                return false;
            };
            let mut scopes = BTreeMap::new();
            scopes.insert("i".to_string(), c.relation.clone());
            let e = Expr::bin(c.op, Expr::field("i", &c.field), Expr::Const(v.clone()));
            sel *= est.conjunct_selectivity(&scopes, &e);
        }
        let mut baseline = prepared.baseline.lock().expect("rebind baseline");
        match *baseline {
            None => {
                *baseline = Some(sel);
                false
            }
            Some(b) => {
                let hi = b.max(sel).max(1e-12);
                let lo = b.min(sel).max(1e-12);
                hi / lo >= REBIND_RATIO
            }
        }
    }

    /// Re-optimize for one outlier binding: inline the literals into the
    /// statement text and plan it like any other query — the optimizer
    /// finally sees the constants (index-set filter lifting, predicate
    /// ordering, join sides), and the rebound plan lands in the plan
    /// cache for repeat outliers. Returns `Ok(None)` when the binding
    /// cannot be inlined (un-renderable value, or the substituted text
    /// fails to plan) — callers fall back to the generic prepared path,
    /// which handles every binding.
    fn execute_rebound(&self, prepared: &Prepared, params: &[Value]) -> Result<Option<Output>> {
        let Some(substituted) = bind_literals(&prepared.sql, params) else {
            return Ok(None);
        };
        let mut eng = self.engine.lock().expect("engine lock");
        let Ok(plan) = eng.plan(&substituted) else {
            return Ok(None);
        };
        let out = eng.execute(&plan)?;
        Ok(Some(out))
    }

    /// Run a compiled program with eligible scans fanned out as morsel
    /// phases on the shared pool — the pool-backed analogue of
    /// `exec::parallel::run_parallel_compiled_with_params`, without
    /// spawning threads: chunks execute on the server's long-lived
    /// workers, interleaved with every other admitted query's chunks.
    fn run_pooled(
        &self,
        qid: u64,
        cp: &Arc<CompiledProgram>,
        slot_params: Vec<Value>,
    ) -> Result<(Output, bool)> {
        let threads = self.pool.workers();
        let mut master = VecState::new(cp);
        master.set_params(slot_params);
        let mut pooled = false;
        for (stmt_idx, s) in cp.body.iter().enumerate() {
            match s {
                // Same eligibility gates as the scoped-thread driver:
                // merge-safe body, zero-init accumulators, and a table
                // big enough to amortize the fan-out. Ordered/bounded
                // emission and distinct iteration stay on the master
                // (scan_parallel_safe excludes them), as does the join
                // driver — the accumulation scan is the serving hot path.
                CStmt::Scan(sl)
                    if threads > 1
                        && scan_parallel_safe(sl)
                        && zero_init_accums(cp, &sl.body)
                        && crate::opt::should_fan_out(sl.table.len(), threads) =>
                {
                    // The equality-filter key is scope-constant: evaluate
                    // once in the master's complete pre-loop state.
                    let filter = match &sl.filter {
                        Some((fid, prog)) => Some((*fid, master.eval_value(cp, prog)?)),
                        None => None,
                    };
                    let len = sl.table.len();
                    let units = len.div_ceil(BATCH);
                    // Workers drain into one collector state; the client
                    // thread merges it into the master after the phase.
                    let collector = Arc::new(Mutex::new(VecState::new(cp)));
                    let run: pool::ChunkFn = {
                        let cp = Arc::clone(cp);
                        let scalars = master.scalars.clone();
                        let params = master.params.clone();
                        let collector = Arc::clone(&collector);
                        Box::new(move |_w, c| {
                            // Re-derive the scan from the owned program:
                            // a `'static` chunk closure cannot borrow
                            // `&ScanLoop` from the caller's frame.
                            let CStmt::Scan(sl) = &cp.body[stmt_idx] else {
                                bail!("pooled phase statement is not a scan");
                            };
                            let len = sl.table.len();
                            let mut st = VecState::new(&cp);
                            st.scalars.clear();
                            st.scalars.extend_from_slice(&scalars);
                            st.set_params(params.clone());
                            st.scan_rows(
                                &cp,
                                sl,
                                filter.as_ref(),
                                c.lo * BATCH,
                                (c.hi * BATCH).min(len),
                            )?;
                            collector
                                .lock()
                                .expect("pooled collector")
                                .absorb(st);
                            Ok(())
                        })
                    };
                    self.pool.run_phase(qid, Policy::Gss, units, run)?;
                    let merged = {
                        let mut guard = collector.lock().expect("pooled collector");
                        std::mem::replace(&mut *guard, VecState::new(cp))
                    };
                    master.absorb(merged);
                    master.note_idiom("vec.morsel");
                    pooled = true;
                }
                other => master.exec_stmts(cp, std::slice::from_ref(other))?,
            }
        }
        Ok((master.finish(cp), pooled))
    }

    /// Plan-cache counters of the wrapped engine:
    /// `(hits, misses, invalidations)`.
    pub fn plan_cache_stats(&self) -> (u64, u64, u64) {
        self.engine.lock().expect("engine lock").plan_cache_stats()
    }

    /// Pool width.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Deepest the admission queue ever got.
    pub fn queued_peak(&self) -> usize {
        self.pool.queued_peak()
    }

    /// Most concurrently open morsel phases ever observed.
    pub fn phases_peak(&self) -> usize {
        self.pool.phases_peak()
    }
}

/// Add an idiom tag once.
fn note_tag(out: &mut Output, tag: &str) {
    if !out.stats.idioms.iter().any(|t| t == tag) {
        out.stats.idioms.push(tag.to_string());
    }
}

/// Parse a `$n` parameter-slot name to its 1-based index.
fn parse_slot(name: &str) -> Option<usize> {
    let n: usize = name.strip_prefix('$')?.parse().ok()?;
    (n >= 1).then_some(n)
}

/// Reorder a 1-based positional binding into `param_names` slot order.
fn slot_order(names: &[String], params: &[Value]) -> Result<Vec<Value>> {
    names
        .iter()
        .map(|n| {
            let idx = parse_slot(n)
                .with_context(|| format!("unrecognized parameter slot `{n}`"))?;
            params
                .get(idx - 1)
                .cloned()
                .with_context(|| format!("no binding for parameter `{n}`"))
        })
        .collect()
}

/// The comparison subset of SQL operators, as IR operators.
fn comparison_op(op: SqlBinOp) -> Option<BinOp> {
    Some(match op {
        SqlBinOp::Eq => BinOp::Eq,
        SqlBinOp::Ne => BinOp::Ne,
        SqlBinOp::Lt => BinOp::Lt,
        SqlBinOp::Le => BinOp::Le,
        SqlBinOp::Gt => BinOp::Gt,
        SqlBinOp::Ge => BinOp::Ge,
        _ => return None,
    })
}

/// Mirror a comparison across its operands (`? < col` ≡ `col > ?`).
fn flip_op(op: BinOp) -> BinOp {
    match op {
        BinOp::Lt => BinOp::Gt,
        BinOp::Le => BinOp::Ge,
        BinOp::Gt => BinOp::Lt,
        BinOp::Ge => BinOp::Le,
        other => other,
    }
}

/// Collect the `column cmp ?` conjuncts of a statement's WHERE clause,
/// with column qualifiers resolved through the FROM/JOIN alias scope
/// (unqualified columns default to the FROM table; a miss only costs the
/// estimator its statistics, never correctness).
fn rebind_conjuncts(select: &sql::Select) -> Vec<RebindConjunct> {
    let Some(filter) = &select.filter else {
        return Vec::new();
    };
    let mut aliases = BTreeMap::new();
    aliases.insert(
        select.alias.clone().unwrap_or_else(|| select.table.clone()),
        select.table.clone(),
    );
    for j in &select.joins {
        aliases.insert(
            j.alias.clone().unwrap_or_else(|| j.table.clone()),
            j.table.clone(),
        );
    }
    let mut conjuncts = Vec::new();
    let mut stack = vec![filter];
    while let Some(e) = stack.pop() {
        match e {
            SqlExpr::Binary {
                op: SqlBinOp::And,
                lhs,
                rhs,
            } => {
                stack.push(lhs);
                stack.push(rhs);
            }
            other => conjuncts.push(other),
        }
    }
    let mut out = Vec::new();
    for c in conjuncts {
        let SqlExpr::Binary { op, lhs, rhs } = c else {
            continue;
        };
        let Some(iop) = comparison_op(*op) else {
            continue;
        };
        let (cr, param, iop) = match (lhs.as_ref(), rhs.as_ref()) {
            (SqlExpr::Column(cr), SqlExpr::Param(n)) => (cr, *n, iop),
            (SqlExpr::Param(n), SqlExpr::Column(cr)) => (cr, *n, flip_op(iop)),
            _ => continue,
        };
        let relation = match &cr.table {
            Some(q) => match aliases.get(q) {
                Some(r) => r.clone(),
                None => continue,
            },
            None => select.table.clone(),
        };
        out.push(RebindConjunct {
            relation,
            field: cr.column.clone(),
            op: iop,
            param,
        });
    }
    out
}

/// Render one value as a SQL literal, or `None` when it has no safe
/// textual form (negative numbers, quotes, non-finite floats — the
/// caller then skips the rebind and executes the generic prepared plan).
fn render_literal(v: &Value) -> Option<String> {
    match v {
        Value::Int(i) if *i >= 0 => Some(i.to_string()),
        Value::Float(x) if *x >= 0.0 && x.is_finite() => Some(format!("{x:?}")),
        Value::Str(s) if !s.contains('\'') => Some(format!("'{s}'")),
        _ => None,
    }
}

/// Substitute a binding into the statement text: `?` placeholders bind
/// left-to-right (matching the parser's numbering), `$n` binds
/// explicitly. Quoted strings pass through untouched.
fn bind_literals(query: &str, params: &[Value]) -> Option<String> {
    let mut out = String::with_capacity(query.len() + 16);
    let mut chars = query.chars().peekable();
    let mut in_str = false;
    let mut next_anon = 0usize;
    while let Some(c) = chars.next() {
        if in_str {
            out.push(c);
            if c == '\'' {
                in_str = false;
            }
            continue;
        }
        match c {
            '\'' => {
                in_str = true;
                out.push(c);
            }
            '?' => {
                let v = params.get(next_anon)?;
                next_anon += 1;
                out.push_str(&render_literal(v)?);
            }
            '$' => {
                let mut digits = String::new();
                while let Some(d) = chars.peek() {
                    if d.is_ascii_digit() {
                        digits.push(*d);
                        chars.next();
                    } else {
                        break;
                    }
                }
                if digits.is_empty() {
                    out.push('$');
                    continue;
                }
                let n: usize = digits.parse().ok()?;
                let v = params.get(n.checked_sub(1)?)?;
                out.push_str(&render_literal(v)?);
            }
            _ => out.push(c),
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Multiset;
    use crate::workload::{access_log_wide, AccessLogSpec};

    const Q: &str = "SELECT url, COUNT(*) FROM access WHERE bytes > ? GROUP BY url";

    fn data() -> Multiset {
        access_log_wide(&AccessLogSpec {
            rows: 20_000,
            urls: 30,
            skew: 1.1,
            seed: 11,
        })
    }

    fn server_over(m: &Multiset, workers: usize) -> Server {
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", m).unwrap();
        Server::new(Engine::new(c), workers, 4)
    }

    fn reference(m: &Multiset, q: &str) -> Output {
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", m).unwrap();
        Engine::new(c).sql(q).unwrap()
    }

    #[test]
    fn prepared_binding_matches_literal_sql() {
        let m = data();
        let srv = server_over(&m, 4);
        let p = srv.prepare(Q).unwrap();
        assert_eq!(p.param_count(), 1);
        let out = srv.execute(&p, &[Value::Int(50_000)]).unwrap();
        let want = reference(&m, "SELECT url, COUNT(*) FROM access WHERE bytes > 50000 GROUP BY url");
        assert!(out.result().unwrap().bag_eq(want.result().unwrap()));
        assert!(out.stats.idioms.iter().any(|t| t == "serve.admit"));
        // A second, ordinary binding: same plan, different result.
        let out2 = srv.execute(&p, &[Value::Int(20_000)]).unwrap();
        let want2 =
            reference(&m, "SELECT url, COUNT(*) FROM access WHERE bytes > 20000 GROUP BY url");
        assert!(out2.result().unwrap().bag_eq(want2.result().unwrap()));
        assert!(
            !out2.stats.idioms.iter().any(|t| t == "opt.rebind"),
            "ordinary binding drift must not re-plan"
        );
    }

    #[test]
    fn statement_compiles_exactly_once_across_prepares_and_executions() {
        let m = data();
        let srv = server_over(&m, 4);
        let p1 = srv.prepare(Q).unwrap();
        assert!(!p1.cache_hit());
        let p2 = srv.prepare(Q).unwrap();
        assert!(p2.cache_hit(), "second prepare must hit the plan cache");
        assert!(Arc::ptr_eq(&p1.compiled, &p2.compiled));
        srv.execute(&p1, &[Value::Int(40_000)]).unwrap();
        let out = srv.execute(&p2, &[Value::Int(45_000)]).unwrap();
        assert!(out.stats.idioms.iter().any(|t| t == "serve.cache_hit"));
        // One miss (the first prepare), one hit (the second); executing
        // twice with different bindings never re-entered the compiler.
        let (hits, misses, invalidations) = srv.plan_cache_stats();
        assert_eq!((hits, misses, invalidations), (1, 1, 0));
    }

    #[test]
    fn big_scans_fan_out_on_the_shared_pool() {
        let m = data();
        let srv = server_over(&m, 4);
        let p = srv.prepare(Q).unwrap();
        let out = srv.execute(&p, &[Value::Int(30_000)]).unwrap();
        assert!(
            out.stats.idioms.iter().any(|t| t == "sched.multi"),
            "20k-row scan should run as pool morsel phases, got {:?}",
            out.stats.idioms
        );
        assert!(out.stats.idioms.iter().any(|t| t == "vec.morsel"));
    }

    #[test]
    fn selectivity_outlier_binding_triggers_a_rebind() {
        let m = data();
        let srv = server_over(&m, 4);
        let p = srv.prepare(Q).unwrap();
        // Baseline: ~50% of the uniform [200, 100000) byte range.
        srv.execute(&p, &[Value::Int(50_000)]).unwrap();
        // Outlier: ~0.1% survives — far past REBIND_RATIO.
        let out = srv.execute(&p, &[Value::Int(99_900)]).unwrap();
        assert!(
            out.stats.idioms.iter().any(|t| t == "opt.rebind"),
            "outlier binding must re-plan, got {:?}",
            out.stats.idioms
        );
        let want =
            reference(&m, "SELECT url, COUNT(*) FROM access WHERE bytes > 99900 GROUP BY url");
        assert!(out.result().unwrap().bag_eq(want.result().unwrap()));
    }

    #[test]
    fn concurrent_executions_share_the_pool_and_stay_correct() {
        let m = data();
        let srv = server_over(&m, 4);
        let p = srv.prepare(Q).unwrap();
        let thresholds: Vec<i64> = (0..8).map(|i| 10_000 + 9_000 * i).collect();
        let outs: Vec<Output> = std::thread::scope(|scope| {
            let handles: Vec<_> = thresholds
                .iter()
                .map(|&t| {
                    let (srv, p) = (&srv, &p);
                    scope.spawn(move || srv.execute(p, &[Value::Int(t)]).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (t, out) in thresholds.iter().zip(&outs) {
            let want = reference(
                &m,
                &format!("SELECT url, COUNT(*) FROM access WHERE bytes > {t} GROUP BY url"),
            );
            assert!(
                out.result().unwrap().bag_eq(want.result().unwrap()),
                "threshold {t} diverged from the sequential oracle"
            );
            assert!(out.stats.idioms.iter().any(|s| s == "serve.admit"));
        }
        // Deterministic admission-bounding coverage lives at the
        // scheduler layer (`sched::tests`); here 8 clients over
        // max_inflight=4 just must all complete correctly.
    }

    #[test]
    fn binding_arity_is_checked() {
        let m = data();
        let srv = server_over(&m, 2);
        let p = srv.prepare(Q).unwrap();
        assert!(srv.execute(&p, &[]).is_err());
        assert!(srv
            .execute(&p, &[Value::Int(1), Value::Int(2)])
            .is_err());
    }

    #[test]
    fn literal_substitution_respects_quotes_and_dollar_slots() {
        let sql = "SELECT * FROM t WHERE a = ? AND b = '?' AND c < $2";
        let bound = bind_literals(sql, &[Value::str("x"), Value::Int(7)]).unwrap();
        assert_eq!(bound, "SELECT * FROM t WHERE a = 'x' AND b = '?' AND c < 7");
        // Un-renderable values refuse substitution instead of corrupting
        // the statement.
        assert!(bind_literals("x > ?", &[Value::Int(-3)]).is_none());
    }
}
