//! The serving layer's long-lived worker pool.
//!
//! One set of OS threads serves EVERY query the server admits — the
//! morsel-driven analogue of a database's shared executor pool, in
//! contrast to `exec::parallel`'s scoped per-query thread spawn. Workers
//! park in [`MultiScheduler::next_chunk`] and pull `(query, chunk)`
//! pairs from whichever admitted queries currently have morsel phases
//! open; the scheduler round-robins across phases, so concurrent
//! queries' chunks interleave fairly instead of executing back-to-back.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::sched::{Chunk, MultiScheduler, Policy};

/// Per-chunk work a phase hands the pool: `(worker, chunk)` → result.
/// Captures everything it needs by `Arc` (the compiled program, scalar
/// and parameter snapshots, the merge collector) because the worker
/// threads outlive any one query.
pub(crate) type ChunkFn = Box<dyn Fn(usize, Chunk) -> Result<()> + Send + Sync>;

/// One open morsel phase: the chunk body plus the first error any chunk
/// produced (remaining chunks still drain — the scheduler has no
/// cancellation — but the phase reports the first failure).
struct PhaseJob {
    run: ChunkFn,
    error: Mutex<Option<anyhow::Error>>,
}

/// A fixed-width worker pool multiplexed across admitted queries by a
/// [`MultiScheduler`]. Dropping the pool shuts the scheduler down and
/// joins every worker.
pub struct SharedPool {
    sched: Arc<MultiScheduler>,
    jobs: Arc<Mutex<BTreeMap<u64, Arc<PhaseJob>>>>,
    workers: Vec<JoinHandle<()>>,
}

impl SharedPool {
    /// Spawn `workers` threads (clamped to at least 1) multiplexed over
    /// at most `max_inflight` concurrently executing queries.
    pub fn new(workers: usize, max_inflight: usize) -> Self {
        let workers = workers.max(1);
        let sched = Arc::new(MultiScheduler::new(workers, max_inflight));
        let jobs: Arc<Mutex<BTreeMap<u64, Arc<PhaseJob>>>> =
            Arc::new(Mutex::new(BTreeMap::new()));
        let handles = (0..workers)
            .map(|w| {
                let sched = Arc::clone(&sched);
                let jobs = Arc::clone(&jobs);
                std::thread::spawn(move || {
                    // Parked in `next_chunk` between phases; `None` only
                    // after shutdown.
                    while let Some((q, chunk)) = sched.next_chunk(w) {
                        let job = jobs.lock().expect("pool jobs lock").get(&q).cloned();
                        let t0 = Instant::now();
                        if let Some(job) = job {
                            if let Err(e) = (job.run)(w, chunk) {
                                let mut slot = job.error.lock().expect("phase error lock");
                                if slot.is_none() {
                                    *slot = Some(e);
                                }
                            }
                        }
                        // Always report — completion tracking must see
                        // every issued chunk, errors included.
                        sched.report(q, w, chunk, t0.elapsed());
                    }
                })
            })
            .collect();
        SharedPool {
            sched,
            jobs,
            workers: handles,
        }
    }

    /// Pool width (phases are scheduled for this worker count).
    pub fn workers(&self) -> usize {
        self.sched.workers()
    }

    /// Admit one query (FIFO, bounded in-flight): returns its unique id
    /// and whether it had to queue.
    pub fn admit(&self) -> (u64, bool) {
        self.sched.admit()
    }

    /// Release an admitted query's execution slot.
    pub fn release(&self, query: u64) {
        self.sched.release(query);
    }

    /// Deepest the admission overflow queue ever got.
    pub fn queued_peak(&self) -> usize {
        self.sched.queued_peak()
    }

    /// Most concurrently open morsel phases ever observed — `>= 2`
    /// proves chunks of different queries actually interleaved.
    pub fn phases_peak(&self) -> usize {
        self.sched.phases_peak()
    }

    /// Run one morsel phase of `units` chunks for admitted query
    /// `query`, blocking until every chunk has executed. Sequential
    /// phases of one query reuse its id.
    pub(crate) fn run_phase(
        &self,
        query: u64,
        policy: Policy,
        units: usize,
        run: ChunkFn,
    ) -> Result<()> {
        let job = Arc::new(PhaseJob {
            run,
            error: Mutex::new(None),
        });
        self.jobs
            .lock()
            .expect("pool jobs lock")
            .insert(query, Arc::clone(&job));
        self.sched.submit(query, policy, units);
        self.sched.wait_done(query);
        self.jobs.lock().expect("pool jobs lock").remove(&query);
        match job.error.lock().expect("phase error lock").take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for SharedPool {
    fn drop(&mut self) {
        self.sched.shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_a_phase_and_reports_errors() {
        let pool = SharedPool::new(4, 4);
        let (q, queued) = pool.admit();
        assert!(!queued);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        pool.run_phase(
            q,
            Policy::Gss,
            10,
            Box::new(move |_w, c| {
                h.fetch_add(c.len(), Ordering::Relaxed);
                Ok(())
            }),
        )
        .unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 10);
        // A failing chunk surfaces as the phase error; the pool survives.
        let err = pool
            .run_phase(
                q,
                Policy::Gss,
                4,
                Box::new(|_w, c| {
                    if c.lo == 0 {
                        anyhow::bail!("chunk zero exploded")
                    }
                    Ok(())
                }),
            )
            .unwrap_err();
        assert!(err.to_string().contains("chunk zero exploded"));
        pool.release(q);
        // Still serviceable after an error.
        let (q2, _) = pool.admit();
        pool.run_phase(q2, Policy::Gss, 3, Box::new(|_w, _c| Ok(())))
            .unwrap();
        pool.release(q2);
    }

    #[test]
    fn concurrent_phases_share_the_pool() {
        let pool = Arc::new(SharedPool::new(4, 8));
        let done = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|scope| {
            for _ in 0..6 {
                let pool = Arc::clone(&pool);
                let done = Arc::clone(&done);
                scope.spawn(move || {
                    let (q, _) = pool.admit();
                    let touched = Arc::new(AtomicUsize::new(0));
                    let t = Arc::clone(&touched);
                    pool.run_phase(
                        q,
                        Policy::Gss,
                        64,
                        Box::new(move |_w, c| {
                            t.fetch_add(c.len(), Ordering::Relaxed);
                            Ok(())
                        }),
                    )
                    .unwrap();
                    assert_eq!(touched.load(Ordering::Relaxed), 64);
                    pool.release(q);
                    done.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(done.load(Ordering::Relaxed), 6);
    }
}
