//! `forelem` — CLI for the compiler-technology Big Data engine.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!
//! ```text
//! forelem compile   --sql Q [--processors N] [--partition-field F]
//!                   [--reformat off|auto|force]    show optimized IR + trace
//! forelem run       --sql Q [--workload access|links|grades] [--rows N]
//!                   [--processors N] [--reformat ...]  compile + execute
//! forelem cluster   --sql Q [--workers N] [--policy P] [--fail W:C]
//!                   [--rows N] [--reformat ...]   distributed execution
//! forelem mapreduce --sql Q                       derive MR pseudo-code (§IV)
//! forelem gen-data  --workload access|links|grades --rows N --out FILE.csv
//! forelem fig2      [--rows N] [--workers N]      mini Figure-2 run
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use forelem::compiler::{CompileOptions, Engine, ReformatMode};
use forelem::coordinator::{ClusterConfig, Failure};
use forelem::ir::Multiset;
use forelem::mapreduce;
use forelem::runtime::Kernels;
use forelem::sched::Policy;
use forelem::storage::StorageCatalog;
use forelem::util::fmt_duration;
use forelem::workload;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "compile" => cmd_compile(&flags),
        "run" => cmd_run(&flags),
        "cluster" => cmd_cluster(&flags),
        "mapreduce" => cmd_mapreduce(&flags),
        "gen-data" => cmd_gen_data(&flags),
        "fig2" => cmd_fig2(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `forelem help`)"),
    }
}

fn print_usage() {
    println!(
        "forelem — compiler-technology alternative for Big Data infrastructures\n\n\
         USAGE: forelem <compile|run|cluster|mapreduce|gen-data|fig2> [flags]\n\n\
         common flags:\n\
           --sql Q              the query (tables: access(url[,agent,bytes]),\n\
                                links(source,target), Grades(studentID,grade,weight))\n\
           --workload W         access | links | grades   (default from query)\n\
           --rows N             workload size              (default 100000)\n\
           --processors N       parallelize IR to N procs  (compile/run)\n\
           --partition-field F  indirect partitioning on F\n\
           --reformat M         off | auto | force         (§III-C1)\n\
           --no-optimize        skip the cost-based optimizer (opt/)\n\
           --workers N          cluster worker count       (cluster/fig2)\n\
           --policy P           static|fixed|gss|trapezoid|factoring|feedback|hybrid\n\
           --fail W:C           inject failure of worker W after C chunks\n\
           --kernels            route integer-keyed aggregation through XLA artifacts"
    );
}

fn parse_flags(args: &[String]) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(name) = a.strip_prefix("--") else {
            bail!("expected flag, found `{a}`");
        };
        if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            out.insert(name.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            out.insert(name.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(out)
}

fn opt_usize(flags: &BTreeMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match flags.get(key) {
        Some(v) => v.parse().with_context(|| format!("bad --{key}")),
        None => Ok(default),
    }
}

fn reformat_mode(flags: &BTreeMap<String, String>) -> Result<ReformatMode> {
    Ok(match flags.get("reformat").map(|s| s.as_str()) {
        None | Some("off") => ReformatMode::Off,
        Some("auto") => ReformatMode::Auto { expected_runs: 10 },
        Some("force") => ReformatMode::Force,
        Some(other) => bail!("bad --reformat `{other}`"),
    })
}

fn policy(flags: &BTreeMap<String, String>) -> Result<Policy> {
    Ok(match flags.get("policy").map(|s| s.as_str()) {
        None | Some("gss") => Policy::Gss,
        Some("static") => Policy::StaticBlock,
        Some("fixed") => Policy::FixedChunk(4096),
        Some("trapezoid") => Policy::Trapezoid,
        Some("factoring") => Policy::Factoring,
        Some("feedback") => Policy::FeedbackGuided,
        Some("hybrid") => Policy::Hybrid {
            super_chunks_per_worker: 4,
        },
        Some(other) => bail!("bad --policy `{other}`"),
    })
}

/// Build the demo catalog for the workload a query references.
fn demo_catalog(flags: &BTreeMap<String, String>, sql: &str) -> Result<StorageCatalog> {
    let rows = opt_usize(flags, "rows", 100_000)?;
    let workload = flags
        .get("workload")
        .cloned()
        .unwrap_or_else(|| infer_workload(sql));
    let mut c = StorageCatalog::new();
    match workload.as_str() {
        "access" => {
            let m = workload::access_log_wide(&workload::AccessLogSpec {
                rows,
                urls: (rows / 20).max(10),
                skew: 1.1,
                seed: 42,
            });
            c.insert_multiset("access", &m)?;
        }
        "links" => {
            let m = workload::link_graph(&workload::LinkGraphSpec {
                edges: rows,
                pages: (rows / 20).max(10),
                skew: 1.05,
                seed: 43,
            });
            c.insert_multiset("links", &m)?;
        }
        "grades" => {
            let m = workload::grades((rows / 10).max(1), 10, 44);
            c.insert_multiset("Grades", &m)?;
        }
        other => bail!("unknown workload `{other}`"),
    }
    Ok(c)
}

fn infer_workload(sql: &str) -> String {
    let l = sql.to_lowercase();
    if l.contains("links") {
        "links".into()
    } else if l.contains("grades") {
        "grades".into()
    } else {
        "access".into()
    }
}

fn engine(flags: &BTreeMap<String, String>) -> Result<Engine> {
    let sql = flags.get("sql").context("missing --sql")?;
    let catalog = demo_catalog(flags, sql)?;
    let mut e = Engine::new(catalog).with_options(CompileOptions {
        processors: opt_usize(flags, "processors", 1)?,
        partition_field: flags.get("partition-field").cloned(),
        reformat: reformat_mode(flags)?,
        optimize: !flags.contains_key("no-optimize"),
    });
    if flags.contains_key("kernels") {
        e = e.with_kernels(Kernels::load_default().context("load XLA artifacts")?);
    }
    Ok(e)
}

fn cmd_compile(flags: &BTreeMap<String, String>) -> Result<()> {
    let sql = flags.get("sql").context("missing --sql")?.clone();
    let mut e = engine(flags)?;
    print!("{}", e.explain(&sql)?);
    Ok(())
}

fn cmd_run(flags: &BTreeMap<String, String>) -> Result<()> {
    let sql = flags.get("sql").context("missing --sql")?.clone();
    let mut e = engine(flags)?;
    let t0 = std::time::Instant::now();
    let out = e.sql(&sql)?;
    let dt = t0.elapsed();
    print_result(out.result(), 10);
    for p in &out.prints {
        println!("{p}");
    }
    println!(
        "-- {} rows visited, {} index builds, {} kernel calls, {}",
        out.stats.rows_visited,
        out.stats.index_builds,
        out.stats.kernel_calls,
        fmt_duration(dt)
    );
    Ok(())
}

fn cmd_cluster(flags: &BTreeMap<String, String>) -> Result<()> {
    let sql = flags.get("sql").context("missing --sql")?.clone();
    let mut e = engine(flags)?;
    let mut cfg = ClusterConfig::new(opt_usize(flags, "workers", 8)?, policy(flags)?);
    if let Some(f) = flags.get("fail") {
        let (w, c) = f
            .split_once(':')
            .context("--fail wants WORKER:AFTER_CHUNKS")?;
        cfg = cfg.with_failure(Failure {
            worker: w.parse()?,
            after_chunks: c.parse()?,
        });
    }
    let (r, m) = e.sql_distributed(&sql, &cfg)?;
    print_result(Some(&m), 10);
    println!(
        "-- policy={} workers={} chunks={} comm={}B recovered={} restarts={} {}",
        cfg.policy.name(),
        cfg.workers,
        r.metrics.chunks,
        r.metrics.comm_bytes,
        r.metrics.failures_recovered,
        r.metrics.restarts,
        fmt_duration(r.metrics.elapsed)
    );
    Ok(())
}

fn cmd_mapreduce(flags: &BTreeMap<String, String>) -> Result<()> {
    let sql = flags.get("sql").context("missing --sql")?.clone();
    let mut e = engine(flags)?;
    let compiled = e.compile(&sql)?;
    let (mr, info) = mapreduce::derive(&compiled.program)?;
    println!("-- derived from the single intermediate (§IV), table `{}`:", info.table);
    println!("{mr}");
    Ok(())
}

fn cmd_gen_data(flags: &BTreeMap<String, String>) -> Result<()> {
    let rows = opt_usize(flags, "rows", 100_000)?;
    let out_path = flags.get("out").context("missing --out")?;
    let kind = flags
        .get("workload")
        .context("missing --workload")?
        .as_str();
    let m: Multiset = match kind {
        "access" => workload::access_log_wide(&workload::AccessLogSpec {
            rows,
            urls: (rows / 20).max(10),
            skew: 1.1,
            seed: 42,
        }),
        "links" => workload::link_graph(&workload::LinkGraphSpec {
            edges: rows,
            pages: (rows / 20).max(10),
            skew: 1.05,
            seed: 43,
        }),
        "grades" => workload::grades((rows / 10).max(1), 10, 44),
        other => bail!("unknown workload `{other}`"),
    };
    let mut f = std::io::BufWriter::new(std::fs::File::create(out_path)?);
    use std::io::Write;
    for row in m.rows() {
        let cells: Vec<String> = row.iter().map(|v| v.to_string()).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    println!("wrote {} rows to {out_path}", m.len());
    Ok(())
}

fn cmd_fig2(flags: &BTreeMap<String, String>) -> Result<()> {
    // A compact version of examples/e2e_fig2.rs for quick CLI smoke runs.
    let rows = opt_usize(flags, "rows", 200_000)?;
    let workers = opt_usize(flags, "workers", 8)?;
    println!("Figure-2 mini run: {rows} rows, {workers} workers (see examples/e2e_fig2.rs for the full experiment)");
    let m = workload::access_log(&workload::AccessLogSpec {
        rows,
        urls: (rows / 20).max(10),
        skew: 1.1,
        seed: 42,
    });
    let table = forelem::storage::Table::from_multiset(&m)?;

    // Hadoop baseline.
    let mr = mapreduce::MapReduceProgram {
        map: mapreduce::MapFn::EmitKeyOne { key_field: 0 },
        reduce: mapreduce::ReduceFn::CountValues,
    };
    let h = mapreduce::run_hadoop(&mapreduce::HadoopConfig::default(), &mr, &table)?;
    println!("  hadoop-sim           {}", fmt_duration(h.metrics.elapsed));

    // forelem, same (string) data.
    let t0 = std::time::Instant::now();
    let job = forelem::coordinator::AggJob::count(std::sync::Arc::new(table.clone()), 0);
    let cfg = ClusterConfig::new(workers, Policy::Gss);
    let r1 = forelem::coordinator::run_job(&cfg, &job)?;
    println!("  forelem (strings)    {}", fmt_duration(t0.elapsed()));
    assert_eq!(r1.pairs.len(), h.pairs.len());

    // forelem, integer-keyed.
    let mut keyed = table;
    keyed.dict_encode_field(0)?;
    let t0 = std::time::Instant::now();
    let job = forelem::coordinator::AggJob::count(std::sync::Arc::new(keyed), 0);
    let r2 = forelem::coordinator::run_job(&cfg, &job)?;
    println!("  forelem (int keyed)  {}", fmt_duration(t0.elapsed()));
    assert_eq!(r2.pairs.len(), r1.pairs.len());
    Ok(())
}

fn print_result(m: Option<&Multiset>, limit: usize) {
    let Some(m) = m else {
        println!("(no result)");
        return;
    };
    println!("{}", m.schema);
    for (i, row) in m.rows().iter().take(limit).enumerate() {
        let cells: Vec<String> = row.iter().map(|v| v.to_string()).collect();
        println!("{:>4}  {}", i, cells.join("  "));
    }
    if m.len() > limit {
        println!("  ... {} rows total", m.len());
    }
}
