//! Binary row files: "Data may be stored by simply storing the tuples as
//! records in a binary file" (§III-C1).
//!
//! This is the on-disk interchange format used by the data importer, the
//! Hadoop-simulator's spill files, and the reformat pass's generated
//! "data load" codes. Format: a small header (magic, field count, field
//! types), then length-prefixed records.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::ir::{DataType, Multiset, Schema, Tuple, Value};

const MAGIC: &[u8; 4] = b"FRL1";

fn dtype_tag(d: DataType) -> u8 {
    match d {
        DataType::Int => 0,
        DataType::Float => 1,
        DataType::Str => 2,
        DataType::Bool => 3,
    }
}

fn tag_dtype(t: u8) -> Result<DataType> {
    Ok(match t {
        0 => DataType::Int,
        1 => DataType::Float,
        2 => DataType::Str,
        3 => DataType::Bool,
        other => bail!("bad dtype tag {other}"),
    })
}

/// Write a multiset to a binary row file.
pub fn write_rows(path: &Path, m: &Multiset) -> Result<()> {
    let file = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&(m.schema.len() as u32).to_le_bytes())?;
    for f in m.schema.fields() {
        w.write_all(&[dtype_tag(f.dtype)])?;
        let name = f.name.as_bytes();
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name)?;
    }
    w.write_all(&(m.len() as u64).to_le_bytes())?;
    for row in m.rows() {
        write_tuple(&mut w, row)?;
    }
    w.flush()?;
    Ok(())
}

/// Read a multiset back from a binary row file.
pub fn read_rows(path: &Path) -> Result<Multiset> {
    let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a forelem row file", path.display());
    }
    let nfields = read_u32(&mut r)? as usize;
    let mut fields = Vec::with_capacity(nfields);
    for _ in 0..nfields {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        let dtype = tag_dtype(tag[0])?;
        let name_len = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        fields.push(crate::ir::Field {
            name: String::from_utf8(name)?,
            dtype,
        });
    }
    let schema = Schema::from_fields(fields);
    let nrows = read_u64(&mut r)? as usize;
    let mut m = Multiset::new(schema.clone());
    for _ in 0..nrows {
        m.push(read_tuple(&mut r, &schema)?);
    }
    Ok(m)
}

/// Serialize one tuple (used standalone by the shuffle/comm layer too).
pub fn write_tuple(w: &mut impl Write, t: &Tuple) -> Result<()> {
    for v in t {
        match v {
            Value::Int(i) => {
                w.write_all(&[0])?;
                w.write_all(&i.to_le_bytes())?;
            }
            Value::Float(f) => {
                w.write_all(&[1])?;
                w.write_all(&f.to_le_bytes())?;
            }
            Value::Str(s) => {
                w.write_all(&[2])?;
                w.write_all(&(s.len() as u32).to_le_bytes())?;
                w.write_all(s.as_bytes())?;
            }
            Value::Bool(b) => {
                w.write_all(&[3, *b as u8])?;
            }
            Value::Null => {
                w.write_all(&[4])?;
            }
        }
    }
    Ok(())
}

/// Deserialize one tuple with the schema's field count.
pub fn read_tuple(r: &mut impl Read, schema: &Schema) -> Result<Tuple> {
    let mut t = Tuple::with_capacity(schema.len());
    for _ in 0..schema.len() {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        t.push(match tag[0] {
            0 => {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                Value::Int(i64::from_le_bytes(b))
            }
            1 => {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                Value::Float(f64::from_le_bytes(b))
            }
            2 => {
                let len = read_u32(r)? as usize;
                let mut s = vec![0u8; len];
                r.read_exact(&mut s)?;
                Value::str(String::from_utf8(s)?)
            }
            3 => {
                let mut b = [0u8; 1];
                r.read_exact(&mut b)?;
                Value::Bool(b[0] != 0)
            }
            4 => Value::Null,
            other => bail!("bad value tag {other}"),
        });
    }
    Ok(t)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// A unique temporary file path (tempfile crate unavailable offline).
pub fn temp_path(prefix: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "forelem-{}-{}-{}",
        prefix,
        std::process::id(),
        n
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Multiset {
        let schema = Schema::new(vec![
            ("url", DataType::Str),
            ("n", DataType::Int),
            ("w", DataType::Float),
            ("ok", DataType::Bool),
        ]);
        let mut m = Multiset::new(schema);
        m.push(vec![
            Value::str("/index.html"),
            Value::Int(-7),
            Value::Float(2.5),
            Value::Bool(true),
        ]);
        m.push(vec![Value::str(""), Value::Int(i64::MAX), Value::Null, Value::Bool(false)]);
        m
    }

    #[test]
    fn roundtrip_all_types() {
        let path = temp_path("rows");
        let m = sample();
        write_rows(&path, &m).unwrap();
        let back = read_rows(&path).unwrap();
        assert!(m.bag_eq(&back));
        assert_eq!(back.schema.field(0).name, "url");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_non_row_file() {
        let path = temp_path("bogus");
        std::fs::write(&path, b"not a row file").unwrap();
        assert!(read_rows(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn temp_paths_are_unique() {
        assert_ne!(temp_path("x"), temp_path("x"));
    }
}
