//! Data import: CSV / log-line readers producing multisets, and the
//! "data load code" generation the paper describes (§III-C1): when the
//! compiler knows the downstream processing, it imports straight into the
//! optimal layout (dictionary-encoded, dead fields dropped) instead of
//! importing raw and reformatting later.

use std::io::BufRead;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::ir::{DataType, Multiset, Schema, Value};

use super::column::{Column, Table};
use super::compressed::CompressedInts;
use super::dict::Dictionary;

/// Parse CSV (no quoting — the synthetic workloads don't need it) into a
/// multiset under the given schema.
pub fn read_csv(r: impl BufRead, schema: &Schema) -> Result<Multiset> {
    let mut m = Multiset::new(schema.clone());
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split(',').collect();
        if parts.len() != schema.len() {
            bail!(
                "line {}: expected {} fields, got {}",
                lineno + 1,
                schema.len(),
                parts.len()
            );
        }
        let tuple = parts
            .iter()
            .zip(schema.fields())
            .map(|(raw, f)| parse_value(raw, f.dtype))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("line {}", lineno + 1))?;
        m.push(tuple);
    }
    Ok(m)
}

fn parse_value(raw: &str, dtype: DataType) -> Result<Value> {
    Ok(match dtype {
        DataType::Int => Value::Int(raw.trim().parse()?),
        DataType::Float => Value::Float(raw.trim().parse()?),
        DataType::Str => Value::str(raw),
        DataType::Bool => Value::Bool(matches!(raw.trim(), "1" | "true" | "TRUE")),
    })
}

/// Import directives produced by the reformat pass: which string fields to
/// dictionary-encode on the way in, and which fields to keep at all.
#[derive(Debug, Clone, Default)]
pub struct ImportPlan {
    /// Field ids to dictionary-encode during import.
    pub dict_encode: Vec<usize>,
    /// Field ids to keep (None = all).
    pub keep: Option<Vec<usize>>,
}

/// The generated "data load code": stream CSV directly into the optimized
/// physical layout, in one pass, without materializing the raw form.
/// Freshly imported integer columns additionally try
/// [`CompressedInts::compress`] — sorted ids become ranges, low-churn
/// status codes become RLE, and anything without a ≥ 2x saving stays a
/// plain `Vec<i64>` — so downstream scans can run in the compressed
/// domain (`Engine::explain` shows the chosen scheme per column).
pub fn import_csv_with_plan(r: impl BufRead, schema: &Schema, plan: &ImportPlan) -> Result<Table> {
    let keep: Vec<usize> = plan
        .keep
        .clone()
        .unwrap_or_else(|| (0..schema.len()).collect());
    let out_schema = schema.project(&keep);

    enum Builder {
        Ints(Vec<i64>),
        Floats(Vec<f64>),
        Strs(Vec<Arc<str>>),
        Bools(Vec<bool>),
        Dict { keys: Vec<u32>, dict: Dictionary },
    }

    let mut builders: Vec<Builder> = keep
        .iter()
        .map(|&src| {
            if plan.dict_encode.contains(&src) {
                Builder::Dict {
                    keys: Vec::new(),
                    dict: Dictionary::new(),
                }
            } else {
                match schema.dtype(src) {
                    DataType::Int => Builder::Ints(Vec::new()),
                    DataType::Float => Builder::Floats(Vec::new()),
                    DataType::Str => Builder::Strs(Vec::new()),
                    DataType::Bool => Builder::Bools(Vec::new()),
                }
            }
        })
        .collect();

    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let parts: Vec<&str> = line.split(',').collect();
        if parts.len() != schema.len() {
            bail!(
                "line {}: expected {} fields, got {}",
                lineno + 1,
                schema.len(),
                parts.len()
            );
        }
        for (b, &src) in builders.iter_mut().zip(&keep) {
            let raw = parts[src];
            match b {
                Builder::Ints(v) => v.push(raw.trim().parse()?),
                Builder::Floats(v) => v.push(raw.trim().parse()?),
                Builder::Strs(v) => v.push(Arc::from(raw)),
                Builder::Bools(v) => v.push(matches!(raw.trim(), "1" | "true" | "TRUE")),
                Builder::Dict { keys, dict } => keys.push(dict.encode(raw)),
            }
        }
    }

    let columns = builders
        .into_iter()
        .map(|b| match b {
            Builder::Ints(v) => match CompressedInts::compress(&v) {
                Some(c) => Column::CompressedInts(c),
                None => Column::Ints(v),
            },
            Builder::Floats(v) => Column::Floats(v),
            Builder::Strs(v) => Column::Strs(v),
            Builder::Bools(v) => Column::Bools(v),
            Builder::Dict { keys, dict } => Column::DictStrs {
                keys,
                dict: Arc::new(dict),
            },
        })
        .collect();
    Table::new(out_schema, columns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn schema() -> Schema {
        Schema::new(vec![
            ("url", DataType::Str),
            ("code", DataType::Int),
            ("ms", DataType::Float),
        ])
    }

    const CSV: &str = "/a,200,1.5\n/b,404,0.25\n/a,200,2.0\n";

    #[test]
    fn read_csv_basic() {
        let m = read_csv(Cursor::new(CSV), &schema()).unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m.get(1, 1), &Value::Int(404));
        assert_eq!(m.get(2, 2), &Value::Float(2.0));
    }

    #[test]
    fn read_csv_rejects_ragged() {
        assert!(read_csv(Cursor::new("/a,200\n"), &schema()).is_err());
        assert!(read_csv(Cursor::new("/a,xyz,1.0\n"), &schema()).is_err());
    }

    #[test]
    fn import_plan_dict_encodes_and_projects() {
        let plan = ImportPlan {
            dict_encode: vec![0],
            keep: Some(vec![0, 2]),
        };
        let t = import_csv_with_plan(Cursor::new(CSV), &schema(), &plan).unwrap();
        assert_eq!(t.schema.len(), 2);
        assert_eq!(t.schema.field(0).name, "url");
        assert_eq!(t.schema.field(1).name, "ms");
        // /a encoded to 0, /b to 1.
        assert_eq!(t.column(0).as_int_keys().unwrap(), vec![0, 1, 0]);
        assert_eq!(t.column(0).dictionary().unwrap().len(), 2);
    }

    #[test]
    fn import_plan_default_keeps_everything_raw() {
        let t =
            import_csv_with_plan(Cursor::new(CSV), &schema(), &ImportPlan::default()).unwrap();
        assert_eq!(t.schema.len(), 3);
        assert_eq!(t.value(0, 0), Value::str("/a"));
        // [200, 404, 200] has no ≥2x-saving layout: it stays plain ints.
        assert_eq!(t.column(1).scheme(), "int");
    }

    #[test]
    fn import_compresses_runny_int_columns() {
        let mut csv = String::new();
        for i in 0..64 {
            csv.push_str(&format!("/u{},{},0.5\n", i % 3, if i < 48 { 200 } else { 404 }));
        }
        let t =
            import_csv_with_plan(Cursor::new(csv), &schema(), &ImportPlan::default()).unwrap();
        // Two long runs of status codes: imported straight into RLE.
        assert_eq!(t.column(1).scheme(), "rle[2 runs]");
        assert_eq!(t.value(0, 1), Value::Int(200));
        assert_eq!(t.value(63, 1), Value::Int(404));
        assert_eq!(t.len(), 64);
    }
}
