//! Compressed column schemes (§III-C1): "a column that enumerates a range
//! of values is not physically stored in full, but rather a description of
//! the value range is stored to be reconstructed when the data is read."
//!
//! Two schemes are implemented and picked automatically:
//! * `Range`  — the column is exactly `start, start+step, ...` (the
//!   paper's enumerated-range case): stored as three integers;
//! * `Rle`    — run-length encoding for low-cardinality columns.
//!
//! The executor operates on these representations directly: equality
//! filters compare once per run ([`CompressedInts::find_eq_in`]), fused
//! aggregations walk [`CompressedInts::run_windows`] and multiply by run
//! length, and residual per-row paths use the prefix-sum `starts` index
//! for O(log runs) random access instead of a linear run scan.

/// A compressed integer column.
#[derive(Debug, Clone)]
pub enum CompressedInts {
    /// `start + i*step` for i in 0..len.
    Range { start: i64, step: i64, len: usize },
    /// Run-length encoded (value, run-length) pairs. `starts[i]` is the
    /// first row covered by run `i` (a prefix sum over run lengths), so
    /// row -> run resolution is a binary search.
    Rle {
        runs: Vec<(i64, u32)>,
        starts: Vec<u32>,
        len: usize,
    },
}

/// Prefix-sum the run lengths: `starts[i]` = first row of run `i`.
fn run_starts(runs: &[(i64, u32)]) -> Vec<u32> {
    let mut starts = Vec::with_capacity(runs.len());
    let mut acc = 0u32;
    for &(_, n) in runs {
        starts.push(acc);
        acc += n;
    }
    starts
}

impl CompressedInts {
    /// Compress, choosing the best applicable scheme; returns None if no
    /// scheme beats plain storage (caller keeps the raw column).
    pub fn compress(values: &[i64]) -> Option<CompressedInts> {
        if values.is_empty() {
            return Some(CompressedInts::Range {
                start: 0,
                step: 0,
                len: 0,
            });
        }
        // Arithmetic range?
        if values.len() >= 2 {
            let step = values[1] - values[0];
            if values
                .windows(2)
                .all(|w| w[1].wrapping_sub(w[0]) == step)
            {
                return Some(CompressedInts::Range {
                    start: values[0],
                    step,
                    len: values.len(),
                });
            }
        } else {
            return Some(CompressedInts::Range {
                start: values[0],
                step: 0,
                len: 1,
            });
        }
        // RLE worth it?
        let mut runs: Vec<(i64, u32)> = Vec::new();
        for &v in values {
            match runs.last_mut() {
                Some((rv, n)) if *rv == v && *n < u32::MAX => *n += 1,
                _ => runs.push((v, 1)),
            }
        }
        // 12 bytes/run vs 8 bytes/value: require at least 2x saving.
        if runs.len() * 12 * 2 <= values.len() * 8 {
            return Some(CompressedInts::from_runs(runs));
        }
        None
    }

    /// Build an RLE column directly from (value, run-length) pairs,
    /// computing the prefix-sum index. Adjacent runs may share a value;
    /// zero-length runs are dropped.
    pub fn from_runs(runs: Vec<(i64, u32)>) -> CompressedInts {
        let runs: Vec<(i64, u32)> = runs.into_iter().filter(|&(_, n)| n > 0).collect();
        let len = runs.iter().map(|&(_, n)| n as usize).sum();
        let starts = run_starts(&runs);
        CompressedInts::Rle { runs, starts, len }
    }

    pub fn len(&self) -> usize {
        match self {
            CompressedInts::Range { len, .. } => *len,
            CompressedInts::Rle { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of runs: 1 for a constant `Range`, `len` for a stepping
    /// `Range` (every row differs), run count for `Rle`. Drives the
    /// optimizer's code-domain vs decode-up-front choice.
    pub fn num_runs(&self) -> usize {
        match self {
            CompressedInts::Range { step: 0, len, .. } => 1.min(*len),
            CompressedInts::Range { len, .. } => *len,
            CompressedInts::Rle { runs, .. } => runs.len(),
        }
    }

    /// The raw (value, run-length) pairs for `Rle` columns.
    pub fn runs(&self) -> Option<&[(i64, u32)]> {
        match self {
            CompressedInts::Range { .. } => None,
            CompressedInts::Rle { runs, .. } => Some(runs),
        }
    }

    /// Random access: O(1) for `Range`, O(log runs) for `Rle` via a
    /// binary search on the prefix-sum `starts` index.
    pub fn get(&self, row: usize) -> i64 {
        match self {
            CompressedInts::Range { start, step, .. } => start + row as i64 * step,
            CompressedInts::Rle { runs, starts, len } => {
                assert!(row < *len, "row {row} out of range");
                let ix = starts.partition_point(|&s| s as usize <= row) - 1;
                runs[ix].0
            }
        }
    }

    /// Iterate the runs overlapping `[lo, hi)` as `(value, run_lo,
    /// run_hi)` with the run bounds clipped to the window. This is the
    /// primitive every run-domain kernel builds on: per-run filter
    /// comparison, count-times-run-length aggregation, and O(runs)
    /// statistics streaming — and it accepts arbitrary sub-ranges so
    /// morsel workers can call it on their own `[lo, hi)` slices.
    pub fn run_windows(&self, lo: usize, hi: usize) -> RunWindows<'_> {
        let hi = hi.min(self.len());
        let lo = lo.min(hi);
        let ix = match self {
            CompressedInts::Range { .. } => 0,
            CompressedInts::Rle { starts, .. } => {
                if lo >= hi {
                    0
                } else {
                    starts.partition_point(|&s| s as usize <= lo) - 1
                }
            }
        };
        RunWindows {
            col: self,
            ix,
            pos: lo,
            hi,
        }
    }

    /// Append the row ids in `[lo, hi)` whose value equals `key` onto
    /// `sel`. `Range` columns solve arithmetically (at most one matching
    /// row unless the step is zero); `Rle` columns compare once per run
    /// and emit whole runs.
    pub fn find_eq_in(&self, key: i64, lo: usize, hi: usize, sel: &mut Vec<usize>) {
        let hi = hi.min(self.len());
        if lo >= hi {
            return;
        }
        match self {
            CompressedInts::Range { start, step: 0, .. } => {
                if *start == key {
                    sel.extend(lo..hi);
                }
            }
            CompressedInts::Range { start, step, .. } => {
                let delta = key - *start;
                if delta % *step == 0 {
                    let row = delta / *step;
                    if row >= 0 && (row as usize) >= lo && (row as usize) < hi {
                        sel.push(row as usize);
                    }
                }
            }
            CompressedInts::Rle { .. } => {
                for (v, rlo, rhi) in self.run_windows(lo, hi) {
                    if v == key {
                        sel.extend(rlo..rhi);
                    }
                }
            }
        }
    }

    /// Reconstruct the full column.
    pub fn decompress(&self) -> Vec<i64> {
        match self {
            CompressedInts::Range { start, step, len } => {
                (0..*len).map(|i| start + i as i64 * step).collect()
            }
            CompressedInts::Rle { runs, len, .. } => {
                let mut out = Vec::with_capacity(*len);
                for &(v, n) in runs {
                    out.extend(std::iter::repeat(v).take(n as usize));
                }
                out
            }
        }
    }

    pub fn heap_bytes(&self) -> usize {
        match self {
            CompressedInts::Range { .. } => 24,
            // 12 bytes per (value, len) pair + 4 per prefix-sum entry.
            CompressedInts::Rle { runs, .. } => runs.len() * 16,
        }
    }

    /// One-word description of the scheme, for `Engine::explain`.
    pub fn scheme(&self) -> String {
        match self {
            CompressedInts::Range { .. } => "range".to_string(),
            CompressedInts::Rle { runs, .. } => format!("rle[{} runs]", runs.len()),
        }
    }
}

/// Iterator over `(value, lo, hi)` run windows; see
/// [`CompressedInts::run_windows`].
pub struct RunWindows<'a> {
    col: &'a CompressedInts,
    ix: usize,
    pos: usize,
    hi: usize,
}

impl Iterator for RunWindows<'_> {
    type Item = (i64, usize, usize);

    fn next(&mut self) -> Option<(i64, usize, usize)> {
        if self.pos >= self.hi {
            return None;
        }
        match self.col {
            CompressedInts::Range { start, step: 0, .. } => {
                let item = (*start, self.pos, self.hi);
                self.pos = self.hi;
                Some(item)
            }
            CompressedInts::Range { start, step, .. } => {
                // Every row is its own run.
                let item = (*start + self.pos as i64 * *step, self.pos, self.pos + 1);
                self.pos += 1;
                Some(item)
            }
            CompressedInts::Rle { runs, starts, .. } => {
                let (v, n) = runs[self.ix];
                let run_end = starts[self.ix] as usize + n as usize;
                let item = (v, self.pos, run_end.min(self.hi));
                self.pos = run_end;
                self.ix += 1;
                Some(item)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_column_compresses_to_constant_size() {
        let values: Vec<i64> = (0..10_000).map(|i| 5 + 3 * i).collect();
        let c = CompressedInts::compress(&values).unwrap();
        assert!(matches!(c, CompressedInts::Range { .. }));
        assert!(c.heap_bytes() < 100);
        assert_eq!(c.decompress(), values);
        assert_eq!(c.get(7), 5 + 21);
    }

    #[test]
    fn low_cardinality_uses_rle() {
        let mut values = vec![7i64; 5000];
        values.extend(vec![9i64; 5000]);
        let c = CompressedInts::compress(&values).unwrap();
        assert!(matches!(c, CompressedInts::Rle { .. }));
        assert_eq!(c.len(), 10_000);
        assert_eq!(c.get(0), 7);
        assert_eq!(c.get(9_999), 9);
        assert_eq!(c.decompress(), values);
    }

    #[test]
    fn incompressible_returns_none() {
        // Pseudo-random values: no range, no useful runs.
        let values: Vec<i64> = (0..1000).map(|i| (i * 2654435761u64 as i64) % 997).collect();
        assert!(CompressedInts::compress(&values).is_none());
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(CompressedInts::compress(&[]).unwrap().len(), 0);
        let one = CompressedInts::compress(&[42]).unwrap();
        assert_eq!(one.decompress(), vec![42]);
    }

    #[test]
    fn indexed_get_agrees_with_linear_decode() {
        let runs: Vec<(i64, u32)> = (0..200).map(|i| (i % 13, 1 + (i % 7) as u32)).collect();
        let c = CompressedInts::from_runs(runs);
        let flat = c.decompress();
        assert_eq!(flat.len(), c.len());
        for (row, &v) in flat.iter().enumerate() {
            assert_eq!(c.get(row), v, "row {row}");
        }
    }

    #[test]
    fn run_windows_clip_to_the_requested_range() {
        let c = CompressedInts::from_runs(vec![(5, 10), (6, 10), (5, 10)]);
        // Window straddles all three runs, cutting the first and last.
        let w: Vec<_> = c.run_windows(3, 27).collect();
        assert_eq!(w, vec![(5, 3, 10), (6, 10, 20), (5, 20, 27)]);
        // Window inside one run.
        assert_eq!(c.run_windows(11, 14).collect::<Vec<_>>(), vec![(6, 11, 14)]);
        // Empty and out-of-range windows yield nothing.
        assert_eq!(c.run_windows(7, 7).count(), 0);
        assert_eq!(c.run_windows(30, 40).count(), 0);
        // Full coverage reconstructs the column.
        let mut flat = Vec::new();
        for (v, lo, hi) in c.run_windows(0, c.len()) {
            flat.extend(std::iter::repeat(v).take(hi - lo));
        }
        assert_eq!(flat, c.decompress());
    }

    #[test]
    fn run_windows_over_range_columns() {
        let c = CompressedInts::Range {
            start: 4,
            step: 2,
            len: 5,
        };
        let w: Vec<_> = c.run_windows(1, 4).collect();
        assert_eq!(w, vec![(6, 1, 2), (8, 2, 3), (10, 3, 4)]);
        let k = CompressedInts::Range {
            start: 9,
            step: 0,
            len: 5,
        };
        assert_eq!(k.run_windows(1, 4).collect::<Vec<_>>(), vec![(9, 1, 4)]);
    }

    #[test]
    fn find_eq_emits_whole_runs_and_solves_ranges() {
        let c = CompressedInts::from_runs(vec![(5, 4), (6, 4), (5, 4)]);
        let mut sel = Vec::new();
        c.find_eq_in(5, 0, 12, &mut sel);
        assert_eq!(sel, vec![0, 1, 2, 3, 8, 9, 10, 11]);
        sel.clear();
        c.find_eq_in(5, 2, 10, &mut sel);
        assert_eq!(sel, vec![2, 3, 8, 9]);
        sel.clear();
        c.find_eq_in(7, 0, 12, &mut sel);
        assert!(sel.is_empty());

        let r = CompressedInts::Range {
            start: 10,
            step: 3,
            len: 100,
        };
        sel.clear();
        r.find_eq_in(10 + 3 * 40, 0, 100, &mut sel);
        assert_eq!(sel, vec![40]);
        sel.clear();
        r.find_eq_in(11, 0, 100, &mut sel); // not on the lattice
        assert!(sel.is_empty());
        sel.clear();
        r.find_eq_in(10 + 3 * 40, 41, 100, &mut sel); // outside the window
        assert!(sel.is_empty());

        let k = CompressedInts::Range {
            start: 8,
            step: 0,
            len: 6,
        };
        sel.clear();
        k.find_eq_in(8, 2, 5, &mut sel);
        assert_eq!(sel, vec![2, 3, 4]);
    }

    #[test]
    fn num_runs_reflects_the_scheme() {
        assert_eq!(CompressedInts::from_runs(vec![(1, 3), (2, 3)]).num_runs(), 2);
        let stepping = CompressedInts::Range {
            start: 0,
            step: 1,
            len: 50,
        };
        assert_eq!(stepping.num_runs(), 50);
        let constant = CompressedInts::Range {
            start: 7,
            step: 0,
            len: 50,
        };
        assert_eq!(constant.num_runs(), 1);
    }
}
