//! Compressed column schemes (§III-C1): "a column that enumerates a range
//! of values is not physically stored in full, but rather a description of
//! the value range is stored to be reconstructed when the data is read."
//!
//! Two schemes are implemented and picked automatically:
//! * `Range`  — the column is exactly `start, start+step, ...` (the
//!   paper's enumerated-range case): stored as three integers;
//! * `Rle`    — run-length encoding for low-cardinality columns.

/// A compressed integer column.
#[derive(Debug, Clone)]
pub enum CompressedInts {
    /// `start + i*step` for i in 0..len.
    Range { start: i64, step: i64, len: usize },
    /// Run-length encoded (value, run-length) pairs.
    Rle { runs: Vec<(i64, u32)>, len: usize },
}

impl CompressedInts {
    /// Compress, choosing the best applicable scheme; returns None if no
    /// scheme beats plain storage (caller keeps the raw column).
    pub fn compress(values: &[i64]) -> Option<CompressedInts> {
        if values.is_empty() {
            return Some(CompressedInts::Range {
                start: 0,
                step: 0,
                len: 0,
            });
        }
        // Arithmetic range?
        if values.len() >= 2 {
            let step = values[1] - values[0];
            if values
                .windows(2)
                .all(|w| w[1].wrapping_sub(w[0]) == step)
            {
                return Some(CompressedInts::Range {
                    start: values[0],
                    step,
                    len: values.len(),
                });
            }
        } else {
            return Some(CompressedInts::Range {
                start: values[0],
                step: 0,
                len: 1,
            });
        }
        // RLE worth it?
        let mut runs: Vec<(i64, u32)> = Vec::new();
        for &v in values {
            match runs.last_mut() {
                Some((rv, n)) if *rv == v && *n < u32::MAX => *n += 1,
                _ => runs.push((v, 1)),
            }
        }
        // 12 bytes/run vs 8 bytes/value: require at least 2x saving.
        if runs.len() * 12 * 2 <= values.len() * 8 {
            return Some(CompressedInts::Rle {
                runs,
                len: values.len(),
            });
        }
        None
    }

    pub fn len(&self) -> usize {
        match self {
            CompressedInts::Range { len, .. } => *len,
            CompressedInts::Rle { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Random access (O(1) for range, O(runs) for RLE — the executor
    /// decompresses up-front for hot loops instead).
    pub fn get(&self, row: usize) -> i64 {
        match self {
            CompressedInts::Range { start, step, .. } => start + row as i64 * step,
            CompressedInts::Rle { runs, .. } => {
                let mut remaining = row;
                for &(v, n) in runs {
                    if remaining < n as usize {
                        return v;
                    }
                    remaining -= n as usize;
                }
                panic!("row {row} out of range");
            }
        }
    }

    /// Reconstruct the full column.
    pub fn decompress(&self) -> Vec<i64> {
        match self {
            CompressedInts::Range { start, step, len } => {
                (0..*len).map(|i| start + i as i64 * step).collect()
            }
            CompressedInts::Rle { runs, len } => {
                let mut out = Vec::with_capacity(*len);
                for &(v, n) in runs {
                    out.extend(std::iter::repeat(v).take(n as usize));
                }
                out
            }
        }
    }

    pub fn heap_bytes(&self) -> usize {
        match self {
            CompressedInts::Range { .. } => 24,
            CompressedInts::Rle { runs, .. } => runs.len() * 12,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_column_compresses_to_constant_size() {
        let values: Vec<i64> = (0..10_000).map(|i| 5 + 3 * i).collect();
        let c = CompressedInts::compress(&values).unwrap();
        assert!(matches!(c, CompressedInts::Range { .. }));
        assert!(c.heap_bytes() < 100);
        assert_eq!(c.decompress(), values);
        assert_eq!(c.get(7), 5 + 21);
    }

    #[test]
    fn low_cardinality_uses_rle() {
        let mut values = vec![7i64; 5000];
        values.extend(vec![9i64; 5000]);
        let c = CompressedInts::compress(&values).unwrap();
        assert!(matches!(c, CompressedInts::Rle { .. }));
        assert_eq!(c.len(), 10_000);
        assert_eq!(c.get(0), 7);
        assert_eq!(c.get(9_999), 9);
        assert_eq!(c.decompress(), values);
    }

    #[test]
    fn incompressible_returns_none() {
        // Pseudo-random values: no range, no useful runs.
        let values: Vec<i64> = (0..1000).map(|i| (i * 2654435761u64 as i64) % 997).collect();
        assert!(CompressedInts::compress(&values).is_none());
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(CompressedInts::compress(&[]).unwrap().len(), 0);
        let one = CompressedInts::compress(&[42]).unwrap();
        assert_eq!(one.decompress(), vec![42]);
    }
}
