//! The storage catalog: named tables + statistics.
//!
//! This is what the execution engine resolves `forelem (i; i ∈ pA)`
//! against, and where the cost model and the cost-based optimizer
//! (`crate::opt`) get their table and column statistics. Per-column
//! [`ColumnStats`] are collected lazily and cached per `(table, field)`;
//! replacing a table (reformat, import) invalidates its cached entries.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::analysis::TableStats;
use crate::ir::{Multiset, Schema};

use super::column::Table;
use super::stats::ColumnStats;

/// A catalog of named tables.
#[derive(Debug, Default)]
pub struct StorageCatalog {
    tables: BTreeMap<String, Arc<Table>>,
    /// Lazily collected per-(table, field) column statistics. Interior
    /// mutability keeps stats collection behind the same shared `&self`
    /// the executors hold; the mutex is uncontended on the hot path
    /// (stats are read at *compile* time, not per row).
    stats_cache: Mutex<BTreeMap<(String, usize), Arc<ColumnStats>>>,
    /// Statistics epoch: bumped on every table insert/replace (any
    /// mutation that can change schemas, cardinalities or cached column
    /// stats). Plan caches key compiled programs on this — a cached plan
    /// whose epoch no longer matches was optimized against stale
    /// statistics and must be recompiled.
    epoch: u64,
}

impl Clone for StorageCatalog {
    fn clone(&self) -> Self {
        StorageCatalog {
            tables: self.tables.clone(),
            stats_cache: Mutex::new(self.stats_cache.lock().unwrap().clone()),
            epoch: self.epoch,
        }
    }
}

/// Concurrent compilation contract: the serving layer hands one catalog
/// to N compiling/executing threads behind a shared reference, so the
/// catalog (tables, stats cache, epoch) must be `Send + Sync`.
const _: fn() = || {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<StorageCatalog>();
};

impl StorageCatalog {
    pub fn new() -> Self {
        StorageCatalog::default()
    }

    pub fn insert(&mut self, name: &str, table: Table) {
        self.invalidate_stats(name);
        self.tables.insert(name.to_string(), Arc::new(table));
    }

    pub fn insert_multiset(&mut self, name: &str, m: &Multiset) -> Result<()> {
        self.insert(name, Table::from_multiset(m)?);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Arc<Table>> {
        self.tables
            .get(name)
            .with_context(|| format!("table `{name}` not in storage catalog"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tables.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tables.keys()
    }

    /// Replace a table (used by the reformat pass). Cached statistics for
    /// the old layout are dropped.
    pub fn replace(&mut self, name: &str, table: Table) {
        self.invalidate_stats(name);
        self.tables.insert(name.to_string(), Arc::new(table));
    }

    fn invalidate_stats(&mut self, name: &str) {
        self.epoch += 1;
        self.stats_cache
            .get_mut()
            .unwrap()
            .retain(|(t, _), _| t != name);
    }

    /// The current statistics epoch (see the field docs). Monotonically
    /// increasing; equal epochs guarantee no table was inserted or
    /// replaced in between.
    pub fn stats_epoch(&self) -> u64 {
        self.epoch
    }

    /// The schema catalog view the SQL front-end needs.
    pub fn schemas(&self) -> BTreeMap<String, Schema> {
        self.tables
            .iter()
            .map(|(n, t)| (n.clone(), t.schema.clone()))
            .collect()
    }

    /// Full statistics for one column, collected on first use and cached
    /// until the table is replaced. This is what the optimizer's
    /// estimator consumes; `stats` below derives the legacy rows+NDV pair
    /// from it.
    pub fn column_stats(&self, name: &str, field: usize) -> Result<Arc<ColumnStats>> {
        let t = self.get(name)?.clone();
        if field >= t.schema.len() {
            bail!(
                "table `{name}` has {} fields, no field {field}",
                t.schema.len()
            );
        }
        let key = (name.to_string(), field);
        if let Some(s) = self.stats_cache.lock().unwrap().get(&key) {
            return Ok(s.clone());
        }
        // Collect outside the lock; a racing duplicate collection is
        // harmless (last write wins, both are correct).
        let stats = Arc::new(ColumnStats::collect(&t, field));
        self.stats_cache.lock().unwrap().insert(key, stats.clone());
        Ok(stats)
    }

    /// Statistics for the cost model: rows + distinct count of a field
    /// (exact for dictionary-encoded fields — the dictionary *is* the
    /// distinct set; singleton-scaled stride sample otherwise, see
    /// `storage::stats`).
    pub fn stats(&self, name: &str, field: Option<usize>) -> Result<TableStats> {
        let t = self.get(name)?;
        let rows = t.len() as u64;
        let distinct = match field {
            Some(f) => self.column_stats(name, f)?.ndv,
            None => 1,
        };
        Ok(TableStats::new(rows, distinct.min(rows.max(1))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Value};

    fn catalog_with_access(n: usize, distinct: usize) -> StorageCatalog {
        let schema = Schema::new(vec![("url", DataType::Str)]);
        let mut m = Multiset::new(schema);
        for i in 0..n {
            m.push(vec![Value::str(format!("/page{}", i % distinct))]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        c
    }

    #[test]
    fn get_and_contains() {
        let c = catalog_with_access(10, 3);
        assert!(c.contains("access"));
        assert!(!c.contains("nope"));
        assert!(c.get("nope").is_err());
        assert_eq!(c.get("access").unwrap().len(), 10);
    }

    #[test]
    fn stats_exact_for_dict_encoded() {
        let mut c = catalog_with_access(1000, 50);
        let mut t = (**c.get("access").unwrap()).clone();
        t.dict_encode_field(0).unwrap();
        c.replace("access", t);
        let s = c.stats("access", Some(0)).unwrap();
        assert_eq!(s.rows, 1000);
        assert_eq!(s.distinct_keys, 50);
    }

    #[test]
    fn stats_sampled_for_plain_strings() {
        let c = catalog_with_access(1000, 50);
        let s = c.stats("access", Some(0)).unwrap();
        assert_eq!(s.rows, 1000);
        // Small columns are scanned fully: the count is exact.
        assert_eq!(s.distinct_keys, 50);
    }

    #[test]
    fn schemas_view_matches() {
        let c = catalog_with_access(5, 2);
        let schemas = c.schemas();
        assert_eq!(schemas["access"].field(0).name, "url");
    }

    #[test]
    fn column_stats_are_cached_and_invalidated_on_replace() {
        let mut c = catalog_with_access(1000, 50);
        let first = c.column_stats("access", 0).unwrap();
        let second = c.column_stats("access", 0).unwrap();
        assert!(Arc::ptr_eq(&first, &second), "second read must hit the cache");
        // Replacing the table drops the cached entry.
        let mut t = (**c.get("access").unwrap()).clone();
        t.dict_encode_field(0).unwrap();
        c.replace("access", t);
        let third = c.column_stats("access", 0).unwrap();
        assert!(!Arc::ptr_eq(&first, &third));
        assert!(third.ndv_exact);
        assert_eq!(third.ndv, 50);
    }

    #[test]
    fn column_stats_rejects_out_of_range_fields() {
        let c = catalog_with_access(10, 3);
        assert!(c.column_stats("access", 7).is_err());
        assert!(c.column_stats("nope", 0).is_err());
    }

    #[test]
    fn stats_epoch_bumps_on_insert_and_replace_only() {
        let mut c = catalog_with_access(100, 5);
        let e0 = c.stats_epoch();
        // Reads (stats collection included) never move the epoch.
        let _ = c.column_stats("access", 0).unwrap();
        let _ = c.schemas();
        assert_eq!(c.stats_epoch(), e0);
        let t = (**c.get("access").unwrap()).clone();
        c.replace("access", t);
        assert_eq!(c.stats_epoch(), e0 + 1);
        let m = Multiset::new(Schema::new(vec![("x", DataType::Int)]));
        c.insert_multiset("other", &m).unwrap();
        assert_eq!(c.stats_epoch(), e0 + 2);
        // Clones carry the epoch (a cloned catalog sees the same stats).
        assert_eq!(c.clone().stats_epoch(), c.stats_epoch());
    }

    #[test]
    fn concurrent_stats_lookups_are_safe_and_converge() {
        // Two threads compiling against one shared catalog race the lazy
        // stats collection: both must get correct stats, and the cache
        // must end up holding exactly one entry they agree with.
        let c = catalog_with_access(10_000, 64);
        let c = &c;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    scope.spawn(move || {
                        let mut ndvs = Vec::new();
                        for _ in 0..50 {
                            ndvs.push(c.column_stats("access", 0).unwrap().ndv);
                        }
                        ndvs
                    })
                })
                .collect();
            for h in handles {
                for ndv in h.join().unwrap() {
                    assert_eq!(ndv, 64);
                }
            }
        });
        // After the race, repeated reads hit one settled cache entry.
        let a = c.column_stats("access", 0).unwrap();
        let b = c.column_stats("access", 0).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn clone_carries_the_cache_independently() {
        let c = catalog_with_access(100, 5);
        let _ = c.column_stats("access", 0).unwrap();
        let c2 = c.clone();
        let s = c2.column_stats("access", 0).unwrap();
        assert_eq!(s.ndv, 5);
    }
}
