//! The storage catalog: named tables + statistics.
//!
//! This is what the execution engine resolves `forelem (i; i ∈ pA)`
//! against, and where the cost model gets its table statistics.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::analysis::TableStats;
use crate::ir::{Multiset, Schema};

use super::column::Table;

/// A catalog of named tables.
#[derive(Debug, Clone, Default)]
pub struct StorageCatalog {
    tables: BTreeMap<String, Arc<Table>>,
}

impl StorageCatalog {
    pub fn new() -> Self {
        StorageCatalog::default()
    }

    pub fn insert(&mut self, name: &str, table: Table) {
        self.tables.insert(name.to_string(), Arc::new(table));
    }

    pub fn insert_multiset(&mut self, name: &str, m: &Multiset) -> Result<()> {
        self.insert(name, Table::from_multiset(m)?);
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Arc<Table>> {
        self.tables
            .get(name)
            .with_context(|| format!("table `{name}` not in storage catalog"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tables.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.tables.keys()
    }

    /// Replace a table (used by the reformat pass).
    pub fn replace(&mut self, name: &str, table: Table) {
        self.tables.insert(name.to_string(), Arc::new(table));
    }

    /// The schema catalog view the SQL front-end needs.
    pub fn schemas(&self) -> BTreeMap<String, Schema> {
        self.tables
            .iter()
            .map(|(n, t)| (n.clone(), t.schema.clone()))
            .collect()
    }

    /// Statistics for the cost model: rows + distinct count of a field
    /// (exact for dictionary-encoded fields — the dictionary *is* the
    /// distinct set; sampled otherwise).
    pub fn stats(&self, name: &str, field: Option<usize>) -> Result<TableStats> {
        let t = self.get(name)?;
        let rows = t.len() as u64;
        let distinct = match field {
            Some(f) => {
                if let Some(dict) = t.column(f).dictionary() {
                    dict.len() as u64
                } else {
                    // Sample up to 4096 rows for a cardinality estimate.
                    let sample = t.len().min(4096);
                    if sample == 0 {
                        1
                    } else {
                        let mut seen = std::collections::HashSet::new();
                        let stride = (t.len() / sample).max(1);
                        for row in (0..t.len()).step_by(stride) {
                            seen.insert(t.value(row, f));
                        }
                        // Scale up the sampled cardinality.
                        ((seen.len() as f64) * (t.len() as f64 / (sample as f64))).max(1.0)
                            as u64
                    }
                }
            }
            None => 1,
        };
        Ok(TableStats::new(rows, distinct.min(rows.max(1))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Value};

    fn catalog_with_access(n: usize, distinct: usize) -> StorageCatalog {
        let schema = Schema::new(vec![("url", DataType::Str)]);
        let mut m = Multiset::new(schema);
        for i in 0..n {
            m.push(vec![Value::str(format!("/page{}", i % distinct))]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        c
    }

    #[test]
    fn get_and_contains() {
        let c = catalog_with_access(10, 3);
        assert!(c.contains("access"));
        assert!(!c.contains("nope"));
        assert!(c.get("nope").is_err());
        assert_eq!(c.get("access").unwrap().len(), 10);
    }

    #[test]
    fn stats_exact_for_dict_encoded() {
        let mut c = catalog_with_access(1000, 50);
        let mut t = (**c.get("access").unwrap()).clone();
        t.dict_encode_field(0).unwrap();
        c.replace("access", t);
        let s = c.stats("access", Some(0)).unwrap();
        assert_eq!(s.rows, 1000);
        assert_eq!(s.distinct_keys, 50);
    }

    #[test]
    fn stats_sampled_for_plain_strings() {
        let c = catalog_with_access(1000, 50);
        let s = c.stats("access", Some(0)).unwrap();
        assert_eq!(s.rows, 1000);
        // Sampled estimate must be in a sane band.
        assert!(s.distinct_keys >= 10 && s.distinct_keys <= 200, "{}", s.distinct_keys);
    }

    #[test]
    fn schemas_view_matches() {
        let c = catalog_with_access(5, 2);
        let schemas = c.schemas();
        assert_eq!(schemas["access"].field(0).name, "url");
    }
}
