//! Per-column statistics for the cost-based optimizer (`crate::opt`).
//!
//! The paper's thesis is that one IR lets compiler optimization and
//! *query* optimization share an infrastructure — and query optimization
//! runs on statistics. A [`ColumnStats`] records what a Selinger-style
//! optimizer needs about one column: row count, number of distinct
//! values (NDV), min/max, null count, and a small equi-width histogram
//! for numeric columns. Collection is a single pass over the column;
//! the [`StorageCatalog`](super::StorageCatalog) caches the result per
//! `(table, field)` and invalidates it when the table is replaced.
//!
//! NDV is **exact** for dictionary-encoded columns (the dictionary *is*
//! the distinct set), for RLE-compressed columns (the run values are
//! streamed in the run domain, never row-expanded), for enumerated
//! ranges (closed form), and for columns small enough to scan fully;
//! otherwise it is estimated from a deterministic stride sample with a
//! singleton-based (GEE-flavoured) scale-up: only values seen exactly
//! once in the sample are evidence of unseen distinct mass, so heavily
//! repeated values do not inflate the estimate. The sampled-row count is
//! the *actual* number of visited rows, not the nominal sample cap —
//! using the cap as the denominator was the scale-up bias this module
//! replaced (see `StorageCatalog::stats`).

use std::collections::HashMap;

use crate::ir::Value;

use super::column::{Column, Table};

/// Cap on rows visited when sampling NDV for unencoded columns.
pub const NDV_SAMPLE_CAP: usize = 4096;

/// Bucket count of the equi-width histograms on numeric columns.
pub const HISTOGRAM_BUCKETS: usize = 16;

/// Statistics about one column of one table.
#[derive(Debug, Clone)]
pub struct ColumnStats {
    /// Rows in the table (= values in the column).
    pub rows: u64,
    /// (Estimated) number of distinct values, always ≥ 1.
    pub ndv: u64,
    /// True when `ndv` was computed exactly (dictionary or full scan).
    pub ndv_exact: bool,
    /// Null values. Columns are typed and dense today, so this is 0; the
    /// field keeps the estimator API stable for nullable imports.
    pub null_count: u64,
    /// Smallest value, `None` for an empty column.
    pub min: Option<Value>,
    /// Largest value, `None` for an empty column.
    pub max: Option<Value>,
    /// Equi-width histogram, numeric columns only.
    pub histogram: Option<Histogram>,
    /// For compressed integer columns, the number of runs (RLE run
    /// count; 1 for a constant range, `rows` for a stepping range).
    /// `None` for uncompressed columns. The optimizer compares this to
    /// `rows` when choosing code-domain vs decode-up-front execution.
    pub run_count: Option<u64>,
}

/// A small equi-width histogram over a numeric column.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Smallest observed value (left edge of bucket 0).
    pub lo: f64,
    /// Largest observed value (right edge of the last bucket).
    pub hi: f64,
    /// Per-bucket row counts.
    pub counts: Vec<u64>,
    /// Total rows counted (the column length).
    pub total: u64,
}

impl Histogram {
    fn build(values: &[f64]) -> Option<Histogram> {
        Histogram::build_from(values.iter().copied())
    }

    /// Two streaming passes (range, then bucket fill) — no intermediate
    /// column copy, so collection over compressed or integer columns
    /// allocates only the 16-bucket count vector.
    fn build_from(values: impl Iterator<Item = f64> + Clone) -> Option<Histogram> {
        Histogram::build_weighted(values.map(|v| (v, 1)))
    }

    /// Weighted variant of [`Histogram::build_from`]: each `(value,
    /// weight)` item counts as `weight` rows. RLE columns stream their
    /// `(run value, run length)` pairs through this, so a histogram over
    /// an n-row column costs O(runs), not O(n).
    fn build_weighted(values: impl Iterator<Item = (f64, u64)> + Clone) -> Option<Histogram> {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        let mut total = 0u64;
        for (v, w) in values.clone() {
            if w == 0 {
                continue;
            }
            lo = lo.min(v);
            hi = hi.max(v);
            total += w;
        }
        if total == 0 || !lo.is_finite() || !hi.is_finite() || lo >= hi {
            // Degenerate (empty, constant or non-finite) columns: NDV and
            // min/max carry all the information a histogram would.
            return None;
        }
        let mut counts = vec![0u64; HISTOGRAM_BUCKETS];
        let width = (hi - lo) / HISTOGRAM_BUCKETS as f64;
        for (v, w) in values {
            let idx = (((v - lo) / width) as usize).min(HISTOGRAM_BUCKETS - 1);
            counts[idx] += w;
        }
        Some(Histogram {
            lo,
            hi,
            counts,
            total,
        })
    }

    /// Estimated fraction of rows with value strictly below `x`, with
    /// linear interpolation inside the bucket containing `x`.
    pub fn fraction_below(&self, x: f64) -> f64 {
        if self.total == 0 || x <= self.lo {
            return 0.0;
        }
        if x >= self.hi {
            return 1.0;
        }
        let width = (self.hi - self.lo) / self.counts.len() as f64;
        let pos = (x - self.lo) / width;
        let idx = (pos as usize).min(self.counts.len() - 1);
        let below: u64 = self.counts[..idx].iter().sum();
        let est = below as f64 + self.counts[idx] as f64 * (pos - idx as f64);
        (est / self.total as f64).clamp(0.0, 1.0)
    }
}

impl ColumnStats {
    /// Collect statistics for `table.column(field)` in one pass (plus a
    /// strided second visit for sampled NDV).
    pub fn collect(table: &Table, field: usize) -> ColumnStats {
        let rows = table.len() as u64;
        let col = table.column(field);
        match col {
            Column::Ints(vals) => {
                let (ndv, ndv_exact) = sampled_ndv(vals.len(), |i| vals[i]);
                ColumnStats {
                    rows,
                    ndv,
                    ndv_exact,
                    null_count: 0,
                    min: vals.iter().min().map(|&v| Value::Int(v)),
                    max: vals.iter().max().map(|&v| Value::Int(v)),
                    histogram: Histogram::build_from(vals.iter().map(|&v| v as f64)),
                    run_count: None,
                }
            }
            Column::CompressedInts(c) => match c.runs() {
                // RLE: stream the (value, run-length) pairs directly —
                // exact NDV, min/max, and a weighted histogram all in
                // O(runs). The previous implementation called `get(i)`
                // per row, and `get` was itself a linear run scan, so
                // collection was accidentally O(n·runs).
                Some(runs) => {
                    let mut seen: HashMap<i64, ()> = HashMap::new();
                    let mut minmax: Option<(i64, i64)> = None;
                    for &(v, _) in runs {
                        seen.insert(v, ());
                        minmax = Some(match minmax {
                            None => (v, v),
                            Some((lo, hi)) => (lo.min(v), hi.max(v)),
                        });
                    }
                    ColumnStats {
                        rows,
                        ndv: (seen.len() as u64).max(1),
                        ndv_exact: true,
                        null_count: 0,
                        min: minmax.map(|(lo, _)| Value::Int(lo)),
                        max: minmax.map(|(_, hi)| Value::Int(hi)),
                        histogram: Histogram::build_weighted(
                            runs.iter().map(|&(v, n)| (v as f64, n as u64)),
                        ),
                        run_count: Some(runs.len() as u64),
                    }
                }
                // Enumerated range: min/max and NDV are closed-form
                // (every row distinct unless the step is zero); the
                // histogram streams the arithmetic sequence, each value
                // an O(1) reconstruction.
                None => {
                    let (min, max, ndv) = if c.is_empty() {
                        (None, None, 1)
                    } else {
                        let (first, last) = (c.get(0), c.get(c.len() - 1));
                        let ndv = if first == last { 1 } else { c.len() as u64 };
                        (
                            Some(Value::Int(first.min(last))),
                            Some(Value::Int(first.max(last))),
                            ndv,
                        )
                    };
                    ColumnStats {
                        rows,
                        ndv,
                        ndv_exact: true,
                        null_count: 0,
                        min,
                        max,
                        histogram: Histogram::build_from((0..c.len()).map(|i| c.get(i) as f64)),
                        run_count: Some(c.num_runs() as u64),
                    }
                }
            },
            Column::Floats(vals) => {
                let (ndv, ndv_exact) = sampled_ndv(vals.len(), |i| vals[i].to_bits());
                let mut min = f64::INFINITY;
                let mut max = f64::NEG_INFINITY;
                for &v in vals {
                    min = min.min(v);
                    max = max.max(v);
                }
                ColumnStats {
                    rows,
                    ndv,
                    ndv_exact,
                    null_count: 0,
                    min: (!vals.is_empty()).then_some(Value::Float(min)),
                    max: (!vals.is_empty()).then_some(Value::Float(max)),
                    histogram: Histogram::build(vals),
                    run_count: None,
                }
            }
            Column::Strs(vals) => {
                let (ndv, ndv_exact) = sampled_ndv(vals.len(), |i| vals[i].clone());
                ColumnStats {
                    rows,
                    ndv,
                    ndv_exact,
                    null_count: 0,
                    min: vals.iter().min().map(|s| Value::Str(s.clone())),
                    max: vals.iter().max().map(|s| Value::Str(s.clone())),
                    histogram: None,
                    run_count: None,
                }
            }
            Column::DictStrs { keys, dict } => {
                // The dictionary is the exact distinct set.
                let strings: Vec<_> = (0..dict.len() as u32)
                    .filter_map(|k| dict.decode(k).cloned())
                    .collect();
                ColumnStats {
                    rows,
                    ndv: (dict.len() as u64).max(1),
                    ndv_exact: true,
                    null_count: 0,
                    min: (!keys.is_empty())
                        .then(|| strings.iter().min().map(|s| Value::Str(s.clone())))
                        .flatten(),
                    max: (!keys.is_empty())
                        .then(|| strings.iter().max().map(|s| Value::Str(s.clone())))
                        .flatten(),
                    histogram: None,
                    run_count: None,
                }
            }
            Column::Bools(vals) => {
                let mut saw = [false, false];
                for &b in vals {
                    saw[b as usize] = true;
                }
                ColumnStats {
                    rows,
                    ndv: (saw[0] as u64 + saw[1] as u64).max(1),
                    ndv_exact: true,
                    null_count: 0,
                    min: vals.iter().min().map(|&b| Value::Bool(b)),
                    max: vals.iter().max().map(|&b| Value::Bool(b)),
                    histogram: None,
                    run_count: None,
                }
            }
        }
    }

    /// Selectivity of an equality predicate on this column (uniform
    /// assumption: 1/NDV).
    pub fn eq_selectivity(&self) -> f64 {
        1.0 / self.ndv.max(1) as f64
    }
}

/// Exact NDV for small columns, singleton-scaled stride-sample estimate
/// otherwise. Returns `(ndv, exact)`; `ndv` is clamped to `[1, n]`.
fn sampled_ndv<T: Eq + std::hash::Hash>(n: usize, get: impl Fn(usize) -> T) -> (u64, bool) {
    if n == 0 {
        return (1, true);
    }
    if n <= NDV_SAMPLE_CAP {
        let mut seen: HashMap<T, ()> = HashMap::with_capacity(n.min(NDV_SAMPLE_CAP));
        for i in 0..n {
            seen.insert(get(i), ());
        }
        return ((seen.len() as u64).max(1), true);
    }
    // Deterministic stride sample. The stride is rounded UP so at most
    // NDV_SAMPLE_CAP rows are visited, and the scale-up denominator is
    // the number of rows actually visited (the old `len/stride` loop
    // visited more rows than its nominal sample size and scaled by the
    // wrong denominator).
    let stride = n.div_ceil(NDV_SAMPLE_CAP).max(1);
    let mut counts: HashMap<T, u64> = HashMap::new();
    let mut sampled = 0u64;
    let mut i = 0;
    while i < n {
        *counts.entry(get(i)).or_insert(0) += 1;
        sampled += 1;
        i += stride;
    }
    let seen = counts.len() as u64;
    let singletons = counts.values().filter(|&&c| c == 1).count() as u64;
    // GEE-flavoured scale-up: values seen 2+ times in the sample are
    // almost surely not unique in the table, so only singletons carry
    // evidence of unseen distinct values.
    let unseen_rows = n as u64 - sampled;
    let est = seen + ((singletons as f64 * unseen_rows as f64) / sampled as f64) as u64;
    (est.clamp(1, n as u64), false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Multiset, Schema};

    fn table_of_strs(vals: Vec<String>) -> Table {
        let mut m = Multiset::new(Schema::new(vec![("s", DataType::Str)]));
        for v in vals {
            m.push(vec![Value::str(v)]);
        }
        Table::from_multiset(&m).unwrap()
    }

    #[test]
    fn exact_ndv_and_minmax_for_small_columns() {
        let t = table_of_strs(vec!["b".into(), "a".into(), "b".into(), "c".into()]);
        let s = ColumnStats::collect(&t, 0);
        assert_eq!(s.rows, 4);
        assert_eq!(s.ndv, 3);
        assert!(s.ndv_exact);
        assert_eq!(s.null_count, 0);
        assert_eq!(s.min, Some(Value::str("a")));
        assert_eq!(s.max, Some(Value::str("c")));
        assert!(s.histogram.is_none());
    }

    #[test]
    fn dict_encoded_ndv_is_exact_from_the_dictionary() {
        let mut t = table_of_strs((0..5000).map(|i| format!("v{}", i % 37)).collect());
        t.dict_encode_field(0).unwrap();
        let s = ColumnStats::collect(&t, 0);
        assert_eq!(s.ndv, 37);
        assert!(s.ndv_exact);
        assert_eq!(s.min, Some(Value::str("v0")));
        assert_eq!(s.max, Some(Value::str("v9")));
    }

    #[test]
    fn sampled_ndv_is_pinned_for_a_known_skewed_column() {
        // 20_000 rows: one hot value everywhere except a unique cold
        // value every 97 rows (207 cold singletons, true NDV = 208).
        // stride = ceil(20000/4096) = 5, so rows 0,5,10,... are visited:
        // 4000 sampled rows, 42 of them cold (i ≡ 0 mod lcm(97,5)=485).
        // est = 43 + 42·(20000−4000)/4000 = 43 + 168 = 211, within 2% of
        // the truth. The old estimator visited len/stride = 5000 rows but
        // scaled every seen value by len/4096 ≈ 4.88 (the wrong
        // denominator), reporting 53·4.88 ≈ 258 for this column.
        let t = table_of_strs(
            (0..20_000)
                .map(|i| {
                    if i % 97 == 0 {
                        format!("cold{i}")
                    } else {
                        "hot".to_string()
                    }
                })
                .collect(),
        );
        let s = ColumnStats::collect(&t, 0);
        assert!(!s.ndv_exact);
        assert_eq!(s.ndv, 211, "deterministic stride sample must pin the estimate");
    }

    #[test]
    fn sampled_ndv_does_not_overshoot_low_cardinality_columns() {
        // 20_000 rows, 8 distinct values: every sampled value repeats, so
        // no singleton scale-up fires and the estimate stays exact-ish.
        let t = table_of_strs((0..20_000).map(|i| format!("k{}", i % 8)).collect());
        let s = ColumnStats::collect(&t, 0);
        assert_eq!(s.ndv, 8, "repeated sample values must not be scaled up");
    }

    #[test]
    fn int_histogram_fractions_are_sane() {
        let mut m = Multiset::new(Schema::new(vec![("n", DataType::Int)]));
        for i in 0..1000i64 {
            m.push(vec![Value::Int(i)]);
        }
        let t = Table::from_multiset(&m).unwrap();
        let s = ColumnStats::collect(&t, 0);
        assert_eq!(s.min, Some(Value::Int(0)));
        assert_eq!(s.max, Some(Value::Int(999)));
        let h = s.histogram.expect("numeric column gets a histogram");
        assert_eq!(h.total, 1000);
        assert!(h.fraction_below(-5.0) == 0.0);
        assert!(h.fraction_below(5000.0) == 1.0);
        let half = h.fraction_below(500.0);
        assert!((half - 0.5).abs() < 0.05, "got {half}");
    }

    #[test]
    fn compressed_int_columns_are_streamed_not_decompressed() {
        use super::super::compressed::CompressedInts;
        // 40 runs of 150 identical values: RLE-compressible.
        let vals: Vec<i64> = (0..6000).map(|i| (i / 150) as i64).collect();
        let c = CompressedInts::compress(&vals).expect("compressible run-length data");
        let t = Table::new(
            Schema::new(vec![("n", DataType::Int)]),
            vec![Column::CompressedInts(c)],
        )
        .unwrap();
        let s = ColumnStats::collect(&t, 0);
        assert_eq!(s.rows, 6000);
        assert_eq!(s.min, Some(Value::Int(0)));
        assert_eq!(s.max, Some(Value::Int(39)));
        // Run-domain streaming makes NDV exact (one distinct value per
        // run value), regardless of the row-sampling cap.
        assert_eq!(s.ndv, 40);
        assert!(s.ndv_exact);
        assert_eq!(s.run_count, Some(40));
        let h = s.histogram.as_ref().expect("weighted histogram over runs");
        assert_eq!(h.total, 6000, "histogram weights must sum to the row count");
    }

    #[test]
    fn many_run_rle_stats_stream_in_run_domain() {
        use super::super::compressed::CompressedInts;
        // 300_000 runs of 3 rows each (900_000 rows). Before the prefix-sum
        // index and run streaming, collection called `get(i)` per row and
        // each `get` was a linear run scan: O(n·runs) ≈ 10^11 steps, i.e.
        // this test would hang. Run streaming finishes in O(runs).
        let runs: Vec<(i64, u32)> = (0..300_000).map(|i| ((i % 1000) as i64, 3)).collect();
        let c = CompressedInts::from_runs(runs);
        let t = Table::new(
            Schema::new(vec![("n", DataType::Int)]),
            vec![Column::CompressedInts(c)],
        )
        .unwrap();
        let s = ColumnStats::collect(&t, 0);
        assert_eq!(s.rows, 900_000);
        assert_eq!(s.ndv, 1000);
        assert!(s.ndv_exact);
        assert_eq!(s.run_count, Some(300_000));
        assert_eq!(s.min, Some(Value::Int(0)));
        assert_eq!(s.max, Some(Value::Int(999)));
        assert_eq!(s.histogram.unwrap().total, 900_000);
    }

    #[test]
    fn empty_column_is_well_formed() {
        let t = table_of_strs(vec![]);
        let s = ColumnStats::collect(&t, 0);
        assert_eq!(s.rows, 0);
        assert_eq!(s.ndv, 1);
        assert!(s.min.is_none() && s.max.is_none());
    }

    #[test]
    fn constant_numeric_column_skips_histogram() {
        let mut m = Multiset::new(Schema::new(vec![("x", DataType::Float)]));
        for _ in 0..100 {
            m.push(vec![Value::Float(2.5)]);
        }
        let t = Table::from_multiset(&m).unwrap();
        let s = ColumnStats::collect(&t, 0);
        assert_eq!(s.ndv, 1);
        assert!(s.histogram.is_none());
    }
}
