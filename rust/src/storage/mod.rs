//! Physical storage under compiler control (§III-C1): row files, typed
//! and dictionary-encoded columns, compressed column schemes, the table
//! catalog, and data import (including generated "data load" codes).

pub mod catalog;
pub mod column;
pub mod compressed;
pub mod dict;
pub mod import;
pub mod row;
pub mod stats;

pub use catalog::StorageCatalog;
pub use column::{Column, Table};
pub use stats::{ColumnStats, Histogram};
pub use compressed::CompressedInts;
pub use dict::Dictionary;
pub use import::{import_csv_with_plan, read_csv, ImportPlan};
pub use row::{read_rows, temp_path, write_rows};
