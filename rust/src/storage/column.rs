//! Columnar storage: typed columns, dictionary-encoded columns, and the
//! `Table` container the execution engine reads.
//!
//! The compiler "determines a physical storage scheme for the data"
//! (§III-C1); a `Table` is one such scheme. Row-major data (straight from
//! import) is a table of per-field columns too — the distinction the
//! Figure-2 "relayout" variant measures is *which columns exist* (dead
//! fields dropped) and *how they are encoded* (strings vs dictionary keys
//! vs compressed), all expressible here.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::ir::{DataType, Multiset, Schema, Tuple, Value};

use super::compressed::CompressedInts;
use super::dict::Dictionary;

/// One typed column.
#[derive(Debug, Clone)]
pub enum Column {
    Ints(Vec<i64>),
    Floats(Vec<f64>),
    Strs(Vec<Arc<str>>),
    Bools(Vec<bool>),
    /// Dictionary-encoded strings: dense u32 keys + shared dictionary.
    DictStrs {
        keys: Vec<u32>,
        dict: Arc<Dictionary>,
    },
    /// Run-length/delta compressed integers (§III-C1 "compressed column
    /// schemes").
    CompressedInts(CompressedInts),
}

impl Column {
    pub fn len(&self) -> usize {
        match self {
            Column::Ints(v) => v.len(),
            Column::Floats(v) => v.len(),
            Column::Strs(v) => v.len(),
            Column::Bools(v) => v.len(),
            Column::DictStrs { keys, .. } => keys.len(),
            Column::CompressedInts(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DataType {
        match self {
            Column::Ints(_) | Column::CompressedInts(_) => DataType::Int,
            Column::Floats(_) => DataType::Float,
            Column::Strs(_) | Column::DictStrs { .. } => DataType::Str,
            Column::Bools(_) => DataType::Bool,
        }
    }

    /// Value at a row (allocates only for strings).
    pub fn value(&self, row: usize) -> Value {
        match self {
            Column::Ints(v) => Value::Int(v[row]),
            Column::Floats(v) => Value::Float(v[row]),
            Column::Strs(v) => Value::Str(v[row].clone()),
            Column::Bools(v) => Value::Bool(v[row]),
            Column::DictStrs { keys, dict } => {
                Value::Str(dict.decode(keys[row]).expect("dict key in range").clone())
            }
            Column::CompressedInts(c) => Value::Int(c.get(row)),
        }
    }

    /// Dense i64 view if this column is (or encodes as) integers:
    /// plain ints and dictionary keys both qualify — this is the fast
    /// path the integer-keyed kernels consume.
    pub fn as_int_keys(&self) -> Option<Vec<i64>> {
        match self {
            Column::Ints(v) => Some(v.clone()),
            Column::DictStrs { keys, .. } => Some(keys.iter().map(|&k| k as i64).collect()),
            Column::CompressedInts(c) => Some(c.decompress()),
            _ => None,
        }
    }

    /// Borrowed i64 slice without copying, when available.
    pub fn int_slice(&self) -> Option<&[i64]> {
        match self {
            Column::Ints(v) => Some(v),
            _ => None,
        }
    }

    pub fn float_slice(&self) -> Option<&[f64]> {
        match self {
            Column::Floats(v) => Some(v),
            _ => None,
        }
    }

    /// Dictionary backing this column, if dictionary-encoded.
    pub fn dictionary(&self) -> Option<&Arc<Dictionary>> {
        match self {
            Column::DictStrs { dict, .. } => Some(dict),
            _ => None,
        }
    }

    /// Human-readable physical scheme of this column, for `explain`
    /// output: `"int"`, `"dict[N keys]"`, `"rle[N runs]"`, `"range"`, ...
    pub fn scheme(&self) -> String {
        match self {
            Column::Ints(_) => "int".into(),
            Column::Floats(_) => "float".into(),
            Column::Strs(_) => "str".into(),
            Column::Bools(_) => "bool".into(),
            Column::DictStrs { dict, .. } => format!("dict[{} keys]", dict.len()),
            Column::CompressedInts(c) => c.scheme(),
        }
    }

    /// Approximate heap bytes (reformat cost model + §Perf accounting).
    pub fn heap_bytes(&self) -> usize {
        match self {
            Column::Ints(v) => v.len() * 8,
            Column::Floats(v) => v.len() * 8,
            Column::Strs(v) => v.iter().map(|s| s.len() + 24).sum(),
            Column::Bools(v) => v.len(),
            Column::DictStrs { keys, dict } => keys.len() * 4 + dict.heap_bytes(),
            Column::CompressedInts(c) => c.heap_bytes(),
        }
    }

    /// Build a column from values of a uniform type.
    pub fn from_values(dtype: DataType, values: impl Iterator<Item = Value>) -> Result<Column> {
        Ok(match dtype {
            DataType::Int => Column::Ints(
                values
                    .map(|v| v.as_int().ok_or_else(|| anyhow::anyhow!("non-int value")))
                    .collect::<Result<_>>()?,
            ),
            DataType::Float => Column::Floats(
                values
                    .map(|v| {
                        v.as_float()
                            .ok_or_else(|| anyhow::anyhow!("non-float value"))
                    })
                    .collect::<Result<_>>()?,
            ),
            DataType::Str => Column::Strs(
                values
                    .map(|v| match v {
                        Value::Str(s) => Ok(s),
                        other => bail!("non-str value {other}"),
                    })
                    .collect::<Result<_>>()?,
            ),
            DataType::Bool => Column::Bools(
                values
                    .map(|v| {
                        v.as_bool()
                            .ok_or_else(|| anyhow::anyhow!("non-bool value"))
                    })
                    .collect::<Result<_>>()?,
            ),
        })
    }
}

/// A table: a schema plus one column per field.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub schema: Schema,
    pub columns: Vec<Column>,
    len: usize,
}

impl Table {
    pub fn new(schema: Schema, columns: Vec<Column>) -> Result<Table> {
        if schema.len() != columns.len() {
            bail!(
                "schema has {} fields but {} columns given",
                schema.len(),
                columns.len()
            );
        }
        let len = columns.first().map(|c| c.len()).unwrap_or(0);
        if columns.iter().any(|c| c.len() != len) {
            bail!("ragged columns");
        }
        Ok(Table {
            schema,
            columns,
            len,
        })
    }

    /// Convert a logical multiset into a (plain, uncompressed) table.
    pub fn from_multiset(m: &Multiset) -> Result<Table> {
        let mut columns = Vec::with_capacity(m.schema.len());
        for (i, f) in m.schema.fields().iter().enumerate() {
            columns.push(Column::from_values(
                f.dtype,
                m.rows().iter().map(|r| r[i].clone()),
            )?);
        }
        Ok(Table {
            schema: m.schema.clone(),
            columns,
            len: m.len(),
        })
    }

    /// Convert back to a logical multiset (tests, result comparison).
    pub fn to_multiset(&self) -> Multiset {
        let mut m = Multiset::new(self.schema.clone());
        for row in 0..self.len {
            m.push(self.tuple(row));
        }
        m
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn value(&self, row: usize, field: usize) -> Value {
        self.columns[field].value(row)
    }

    pub fn tuple(&self, row: usize) -> Tuple {
        self.columns.iter().map(|c| c.value(row)).collect()
    }

    pub fn column(&self, field: usize) -> &Column {
        &self.columns[field]
    }

    /// Total approximate heap bytes.
    pub fn heap_bytes(&self) -> usize {
        self.columns.iter().map(|c| c.heap_bytes()).sum()
    }

    /// Dictionary-encode one string field in place, returning the shared
    /// dictionary (the §III-C1 integer-keying reformat).
    pub fn dict_encode_field(&mut self, field: usize) -> Result<Arc<Dictionary>> {
        let col = &self.columns[field];
        let Column::Strs(values) = col else {
            bail!(
                "field {} is {:?}, not a plain string column",
                field,
                col.dtype()
            );
        };
        let mut dict = Dictionary::new();
        let keys: Vec<u32> = values.iter().map(|s| dict.encode(s)).collect();
        let dict = Arc::new(dict);
        self.columns[field] = Column::DictStrs {
            keys,
            dict: dict.clone(),
        };
        Ok(dict)
    }

    /// Try to compress one integer field in place (the §III-C1 compressed
    /// column scheme). Returns `true` when `CompressedInts::compress`
    /// accepted the column — it declines layouts with < 2x space saving,
    /// in which case the column is left as plain ints.
    pub fn compress_int_field(&mut self, field: usize) -> Result<bool> {
        let col = &self.columns[field];
        let Column::Ints(values) = col else {
            bail!(
                "field {} is {:?}, not a plain integer column",
                field,
                col.dtype()
            );
        };
        match CompressedInts::compress(values) {
            Some(c) => {
                self.columns[field] = Column::CompressedInts(c);
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Drop all fields except `keep` (dead-field elimination).
    pub fn project(&self, keep: &[usize]) -> Table {
        Table {
            schema: self.schema.project(keep),
            columns: keep.iter().map(|&i| self.columns[i].clone()).collect(),
            len: self.len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DataType;

    fn access() -> Table {
        let schema = Schema::new(vec![("url", DataType::Str), ("ms", DataType::Int)]);
        let m = Multiset::with_rows(
            schema,
            vec![
                vec![Value::str("/a"), Value::Int(10)],
                vec![Value::str("/b"), Value::Int(20)],
                vec![Value::str("/a"), Value::Int(30)],
            ],
        );
        Table::from_multiset(&m).unwrap()
    }

    #[test]
    fn multiset_roundtrip() {
        let t = access();
        let m = t.to_multiset();
        assert_eq!(m.len(), 3);
        assert_eq!(m.get(2, 0), &Value::str("/a"));
        assert_eq!(m.get(1, 1), &Value::Int(20));
    }

    #[test]
    fn dict_encoding_preserves_values_and_shrinks() {
        let mut t = access();
        let before = t.heap_bytes();
        let dict = t.dict_encode_field(0).unwrap();
        assert_eq!(dict.len(), 2);
        assert_eq!(t.value(0, 0), Value::str("/a"));
        assert_eq!(t.value(2, 0), Value::str("/a"));
        // Keys become the dense integer view the kernels consume.
        assert_eq!(t.column(0).as_int_keys().unwrap(), vec![0, 1, 0]);
        let _ = before; // size may grow on tiny tables; key point is the view
    }

    #[test]
    fn dict_encoding_requires_string_column() {
        let mut t = access();
        assert!(t.dict_encode_field(1).is_err());
    }

    #[test]
    fn compress_int_field_swaps_scheme_when_profitable() {
        let schema = Schema::new(vec![("k", DataType::Int)]);
        let m = Multiset::with_rows(
            schema.clone(),
            (0..64i64).map(|i| vec![Value::Int(i / 16)]).collect(),
        );
        let mut t = Table::from_multiset(&m).unwrap();
        assert!(t.compress_int_field(0).unwrap());
        assert_eq!(t.column(0).scheme(), "rle[4 runs]");
        assert_eq!(t.value(63, 0), Value::Int(3));

        // Incompressible layouts are left as plain ints.
        let m = Multiset::with_rows(
            schema,
            vec![
                vec![Value::Int(200)],
                vec![Value::Int(404)],
                vec![Value::Int(200)],
            ],
        );
        let mut t = Table::from_multiset(&m).unwrap();
        assert!(!t.compress_int_field(0).unwrap());
        assert_eq!(t.column(0).scheme(), "int");

        // Non-integer columns are rejected outright.
        let mut t = access();
        assert!(t.compress_int_field(0).is_err());
    }

    #[test]
    fn projection_drops_columns() {
        let t = access().project(&[0]);
        assert_eq!(t.schema.len(), 1);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn ragged_columns_rejected() {
        let schema = Schema::new(vec![("a", DataType::Int), ("b", DataType::Int)]);
        let r = Table::new(schema, vec![Column::Ints(vec![1]), Column::Ints(vec![1, 2])]);
        assert!(r.is_err());
    }
}
