//! String dictionaries: the §III-C1 / Figure-2 "integer keyed" reformat.
//!
//! "the strings (URLs and hosts) in the arrays have been replaced with
//! integer keys. These integer keys are used to subscript another array,
//! which contains the string value for each key. In fact, the data model
//! has been made relational."
//!
//! A `Dictionary` is exactly that subscript array plus the reverse map
//! used while encoding. Once encoded, the hot loops operate on dense
//! `i64` keys — which is also what lets them route into the XLA/Pallas
//! artifacts (integer tensors).

use std::collections::HashMap;
use std::sync::Arc;

/// An append-only string dictionary. Key k maps to the k-th inserted
/// distinct string.
#[derive(Debug, Default, Clone)]
pub struct Dictionary {
    by_key: Vec<Arc<str>>,
    by_str: HashMap<Arc<str>, u32>,
}

impl Dictionary {
    pub fn new() -> Self {
        Dictionary::default()
    }

    /// Encode one string, inserting it if new.
    pub fn encode(&mut self, s: &str) -> u32 {
        if let Some(&k) = self.by_str.get(s) {
            return k;
        }
        let arc: Arc<str> = Arc::from(s);
        let k = self.by_key.len() as u32;
        self.by_key.push(arc.clone());
        self.by_str.insert(arc, k);
        k
    }

    /// Look up an existing string without inserting.
    pub fn lookup(&self, s: &str) -> Option<u32> {
        self.by_str.get(s).copied()
    }

    /// Decode a key back to its string.
    pub fn decode(&self, k: u32) -> Option<&Arc<str>> {
        self.by_key.get(k as usize)
    }

    /// Number of distinct strings.
    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }

    /// Approximate heap footprint in bytes (for the reformat cost model).
    pub fn heap_bytes(&self) -> usize {
        self.by_key.iter().map(|s| s.len() + 16).sum::<usize>() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_is_idempotent() {
        let mut d = Dictionary::new();
        let a = d.encode("x");
        let b = d.encode("y");
        assert_eq!(d.encode("x"), a);
        assert_ne!(a, b);
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn decode_roundtrip() {
        let mut d = Dictionary::new();
        for s in ["alpha", "beta", "gamma"] {
            let k = d.encode(s);
            assert_eq!(d.decode(k).unwrap().as_ref(), s);
        }
        assert!(d.decode(99).is_none());
    }

    #[test]
    fn lookup_does_not_insert() {
        let mut d = Dictionary::new();
        d.encode("present");
        assert_eq!(d.lookup("present"), Some(0));
        assert_eq!(d.lookup("absent"), None);
        assert_eq!(d.len(), 1);
    }
}
