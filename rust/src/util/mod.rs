//! In-tree replacements for crates unavailable in the offline environment:
//! PRNG + distributions (`rng`), a bench harness (`bench`), and
//! seed-driven property testing (`check`).

pub mod bench;
pub mod fxhash;
pub mod check;
pub mod rng;

pub use bench::{fmt_duration, time_fn, write_bench_json, BenchTable, Stats};
pub use check::forall_seeds;
pub use fxhash::{FxHashMap, FxHasher};
pub use rng::{Rng, Zipf};
