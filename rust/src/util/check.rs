//! Tiny property-testing helpers (proptest is not available offline).
//!
//! `forall_seeds` drives a property over many deterministic RNG seeds and
//! reports the first failing seed — enough to express the coordinator /
//! scheduler invariants DESIGN.md calls for, with reproducible shrinking
//! by seed.

use super::rng::Rng;

/// Run `prop` for `cases` seeds; panic with the failing seed on error.
pub fn forall_seeds(cases: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Assert-style helper returning Err instead of panicking, for use inside
/// `forall_seeds` properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        forall_seeds(50, |rng| {
            let x = rng.below(100);
            prop_assert!(x < 100, "x={x} out of range");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn reports_failing_seed() {
        forall_seeds(50, |rng| {
            // Deterministic failure partway through the seed range.
            let x = rng.below(25);
            prop_assert!(x != 7, "hit the answer x={x}");
            Ok(())
        });
    }
}
