//! A fast, non-cryptographic hasher for the aggregation hot paths
//! (rustc-hash/FxHash style; the `rustc-hash` crate is not available
//! offline). Rust's default SipHash is DoS-resistant but ~3-5x slower on
//! short string keys — exactly the workload of the Figure-2 counting
//! loops. See EXPERIMENTS.md §Perf for the measured effect.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash: multiply-rotate word-at-a-time hashing.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

/// HashMap with the fast hasher.
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_short_strings() {
        // Not a statistical test — just confirm no catastrophic clumping
        // over a realistic URL key set.
        let mut buckets = [0usize; 64];
        for i in 0..10_000 {
            let key = format!("http://example.org/site{}/page{}.html", i % 997, i);
            let mut h = FxHasher::default();
            h.write(key.as_bytes());
            buckets[(h.finish() % 64) as usize] += 1;
        }
        let max = *buckets.iter().max().unwrap();
        let min = *buckets.iter().min().unwrap();
        assert!(max < min * 3, "clumpy: {min}..{max}");
    }

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<String, i32> = FxHashMap::default();
        m.insert("a".into(), 1);
        m.insert("b".into(), 2);
        assert_eq!(m["a"], 1);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn deterministic() {
        let h = |s: &str| {
            let mut h = FxHasher::default();
            h.write(s.as_bytes());
            h.finish()
        };
        assert_eq!(h("hello"), h("hello"));
        assert_ne!(h("hello"), h("hellp"));
    }
}
