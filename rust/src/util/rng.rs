//! Deterministic PRNG + distributions (no external `rand` available).
//!
//! SplitMix64 core with helpers for the distributions the workload
//! generators need: uniform ranges, zipfian (the standard model for web
//! URL popularity — the Figure-2 access-log workload), and shuffles.

/// SplitMix64: tiny, fast, solid 64-bit generator. Deterministic per seed,
/// so every experiment in EXPERIMENTS.md is reproducible bit-for-bit.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound` must be nonzero.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift rejection-free mapping (slightly biased for huge
        // bounds; irrelevant for workload generation).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipf-distributed sampler over ranks `0..n` with exponent `s`.
///
/// Uses the classic inverse-CDF-over-precomputed-prefix table: O(n) setup,
/// O(log n) per sample. Rank 0 is the most popular item.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
            cdf.push(total);
        }
        let norm = total;
        for c in &mut cdf {
            *c /= norm;
        }
        Zipf { cdf }
    }

    /// Draw a rank in `[0, n)`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap_or(std::cmp::Ordering::Equal))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zipf_is_skewed_toward_low_ranks() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::new(3);
        let mut counts = vec![0usize; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Rank 0 must dominate rank 100 by a wide margin under s=1.1.
        assert!(counts[0] > counts[100] * 5, "{} vs {}", counts[0], counts[100]);
        // And everything must be in range / total preserved.
        assert_eq!(counts.iter().sum::<usize>(), 100_000);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut xs: Vec<u32> = (0..100).collect();
        Rng::new(4).shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
