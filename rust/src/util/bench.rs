//! Minimal benchmark harness (criterion is not available offline).
//!
//! Provides warmup + repeated timing with median/mean/min reporting, and
//! a `BenchTable` that prints paper-style rows. Every `benches/*.rs`
//! binary uses this; output goes to stdout so `cargo bench | tee` captures
//! it for EXPERIMENTS.md.

use std::time::{Duration, Instant};

/// Time one closure: `warmup` untimed runs, then `iters` timed runs.
pub fn time_fn<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    Stats::from_samples(samples)
}

/// Timing statistics over a set of samples.
#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: Vec<Duration>,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Self {
        samples.sort();
        Stats { samples }
    }

    pub fn min(&self) -> Duration {
        self.samples[0]
    }

    pub fn median(&self) -> Duration {
        self.samples[self.samples.len() / 2]
    }

    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }
}

/// Human-friendly duration.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} µs", s * 1e6)
    }
}

/// Assemble the machine-readable record [`write_bench_json`] emits. No
/// serde offline: the fields are flat and the names are plain ASCII
/// identifiers, so the JSON is built by hand.
fn bench_json(bench: &str, rows: usize, medians_ns: &[(&str, u128)], speedup: f64) -> String {
    let results: Vec<String> = medians_ns
        .iter()
        .map(|(name, ns)| format!("{{\"name\": \"{name}\", \"median_ns\": {ns}}}"))
        .collect();
    format!(
        "{{\"bench\": \"{bench}\", \"rows\": {rows}, \"results\": [{}], \"speedup\": {speedup:.3}}}\n",
        results.join(", ")
    )
}

/// Write one machine-readable benchmark record to `BENCH_<bench>.json`
/// in the current directory. CI uploads `BENCH_*.json` as artifacts so
/// the perf trajectory is tracked PR-over-PR: bench name, row count,
/// per-variant median nanoseconds, and the bench's headline speedup.
pub fn write_bench_json(
    bench: &str,
    rows: usize,
    medians_ns: &[(&str, u128)],
    speedup: f64,
) -> std::io::Result<std::path::PathBuf> {
    let path = std::path::PathBuf::from(format!("BENCH_{bench}.json"));
    std::fs::write(&path, bench_json(bench, rows, medians_ns, speedup))?;
    Ok(path)
}

/// A named-row results table, printed like the paper's figures report.
pub struct BenchTable {
    title: String,
    rows: Vec<(String, Stats, Option<f64>)>,
}

impl BenchTable {
    pub fn new(title: &str) -> Self {
        println!("\n== {title} ==");
        BenchTable {
            title: title.to_string(),
            rows: Vec::new(),
        }
    }

    /// Run and record one row.
    pub fn row<T>(&mut self, name: &str, warmup: usize, iters: usize, f: impl FnMut() -> T) {
        let stats = time_fn(warmup, iters, f);
        println!(
            "  {name:<40} median {:>12}  min {:>12}",
            fmt_duration(stats.median()),
            fmt_duration(stats.min())
        );
        self.rows.push((name.to_string(), stats, None));
    }

    /// Record an externally-measured duration (one-shot runs).
    pub fn record(&mut self, name: &str, d: Duration) {
        println!("  {name:<40} one-shot {:>11}", fmt_duration(d));
        self.rows
            .push((name.to_string(), Stats::from_samples(vec![d]), None));
    }

    /// Print speedups relative to the named baseline row.
    pub fn summarize_vs(&self, baseline: &str) {
        let Some(base) = self
            .rows
            .iter()
            .find(|(n, _, _)| n == baseline)
            .map(|(_, s, _)| s.median().as_secs_f64())
        else {
            return;
        };
        println!("  -- speedups vs `{baseline}` ({}):", self.title);
        for (name, stats, _) in &self.rows {
            if name != baseline {
                let f = base / stats.median().as_secs_f64();
                println!("     {name:<37} {f:>8.2}x");
            }
        }
    }

    pub fn rows(&self) -> impl Iterator<Item = (&str, &Stats)> {
        self.rows.iter().map(|(n, s, _)| (n.as_str(), s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(3),
            Duration::from_millis(1),
            Duration::from_millis(2),
        ]);
        assert_eq!(s.min(), Duration::from_millis(1));
        assert_eq!(s.median(), Duration::from_millis(2));
        assert_eq!(s.mean(), Duration::from_millis(2));
    }

    #[test]
    fn fmt_picks_unit() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_duration(Duration::from_micros(5)).ends_with(" µs"));
    }

    #[test]
    fn time_fn_runs_the_closure() {
        let mut n = 0;
        let _ = time_fn(2, 3, || n += 1);
        assert_eq!(n, 5);
    }

    #[test]
    fn bench_json_shape_is_stable() {
        let j = bench_json(
            "parallel_scan",
            200_000,
            &[("compiled-1-thread", 1_500_000), ("compiled-4-threads", 500_000)],
            3.0,
        );
        assert_eq!(
            j,
            "{\"bench\": \"parallel_scan\", \"rows\": 200000, \"results\": \
             [{\"name\": \"compiled-1-thread\", \"median_ns\": 1500000}, \
             {\"name\": \"compiled-4-threads\", \"median_ns\": 500000}], \
             \"speedup\": 3.000}\n"
        );
    }
}
