//! Analyses over the single intermediate representation: def-use (§II),
//! dependence tests for reordering/fusion legality (§III-A4), and the
//! cost model driving index-set materialization (§II, Figure 1).

pub mod cost;
pub mod defuse;
pub mod dependence;

pub use cost::{choose_strategy, lookup_cost, scan_cost, TableStats};
pub use defuse::{program_defuse, stmt_defuse, DefUse};
pub use dependence::{can_fuse, can_reorder, is_parallelizable, same_domain};
