//! Dependence tests between adjacent loops — the legality oracle for
//! statement reordering and Loop Fusion (§III-A4).
//!
//! The paper reorders two parallelized counting loops next to each other
//! "because these loops do not have a dependency on the other loops";
//! this module decides exactly that from def-use sets.

use crate::ir::{Domain, Loop, LoopKind, Stmt};

use super::defuse::stmt_defuse;

/// Can `a` and `b` (two statements in the same body) be swapped?
pub fn can_reorder(a: &Stmt, b: &Stmt) -> bool {
    let da = stmt_defuse(a, &[]);
    let db = stmt_defuse(b, &[]);
    !da.conflicts_with(&db)
}

/// Can two adjacent loops be fused into one?
///
/// Requirements (conservative):
/// * same kind;
/// * identical iteration domain (same index set / same range bounds /
///   same value-partition source);
/// * bodies don't carry a cross-iteration dependence through an array
///   indexed differently — approximated by requiring the bodies not to
///   write any array/result the other body reads or writes *unless* the
///   domain is identical, in which case iteration-wise interleaving is
///   exactly the sequential execution of both bodies for each element.
///
/// With identical domains, fusing `for x { A } ; for x { B }` into
/// `for x { A; B }` is legal when B does not read state A writes *for a
/// different iteration point*. Our accumulator arrays are only read back
/// by reduction loops (distinct iteration), never inside the producing
/// loop, so the body-level check reduces to: B must not read any array A
/// writes (and vice versa for anti-dependence), and they must not write
/// the same result multiset (which would change interleaving order — but
/// multisets are order-free, so result/result is allowed).
pub fn can_fuse(a: &Loop, b: &Loop) -> bool {
    if a.kind != b.kind {
        return false;
    }
    if !same_domain(&a.domain, &b.domain) {
        return false;
    }
    let da = stmt_defuse(&Stmt::Loop(a.clone()), &[]);
    let db = stmt_defuse(&Stmt::Loop(b.clone()), &[]);
    // Flow/anti dependences through arrays forbid fusion; shared scalar
    // writes likewise. Shared *result* appends are fine (bag semantics).
    let arrays_conflict = da
        .arrays_def
        .intersection(&db.arrays_use)
        .next()
        .is_some()
        || db.arrays_def.intersection(&da.arrays_use).next().is_some()
        || da.arrays_def.intersection(&db.arrays_def).next().is_some();
    let scalars_conflict = da
        .scalars_def
        .intersection(&db.scalars_def)
        .next()
        .is_some()
        || da.scalars_def.intersection(&db.scalars_use).next().is_some()
        || db.scalars_def.intersection(&da.scalars_use).next().is_some();
    !arrays_conflict && !scalars_conflict
}

/// Structural domain equality modulo the loop variable name.
pub fn same_domain(a: &Domain, b: &Domain) -> bool {
    match (a, b) {
        (Domain::IndexSet(x), Domain::IndexSet(y)) => {
            x.relation == y.relation
                && x.field_filter == y.field_filter
                && x.distinct == y.distinct
                && x.partition == y.partition
        }
        (Domain::Range { lo: a0, hi: a1 }, Domain::Range { lo: b0, hi: b1 }) => {
            a0 == b0 && a1 == b1
        }
        (
            Domain::ValuePartition {
                relation: r1,
                field: f1,
                part: p1,
                parts: n1,
            },
            Domain::ValuePartition {
                relation: r2,
                field: f2,
                part: p2,
                parts: n2,
            },
        ) => r1 == r2 && f1 == f2 && p1 == p2 && n1 == n2,
        (
            Domain::DistinctValues {
                relation: r1,
                field: f1,
            },
            Domain::DistinctValues {
                relation: r2,
                field: f2,
            },
        ) => r1 == r2 && f1 == f2,
        _ => false,
    }
}

/// Is this loop parallel-safe: a forelem/forall whose body carries no
/// loop-carried dependence? Accumulator updates with commutative ops and
/// result appends are reduction-style and parallelize with per-partition
/// privatization (what the data-partitioning transforms generate), so the
/// check is that the body contains no scalar assignment (non-reducible
/// state) and no nested read of an array it also writes at a *different*
/// subscript. We approximate the latter conservatively: any `Set`
/// accumulation blocks parallelization.
pub fn is_parallelizable(l: &Loop) -> bool {
    if l.kind == LoopKind::For {
        return false;
    }
    let mut ok = true;
    for s in &l.body {
        s.walk(&mut |sub| match sub {
            Stmt::Assign { .. } => ok = false,
            Stmt::Accum { op, .. } if *op == crate::ir::AccumOp::Set => ok = false,
            _ => {}
        });
    }
    ok
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{AccumOp, Expr, IndexSet, Stmt};

    fn count(array: &str, field: &str) -> Loop {
        Loop::forelem(
            "i",
            IndexSet::all("T"),
            vec![Stmt::increment(array, vec![Expr::field("i", field)])],
        )
    }

    fn reduce(array: &str, field: &str) -> Loop {
        Loop::forelem(
            "i",
            IndexSet::distinct_of("T", field),
            vec![Stmt::result_union(
                "R",
                vec![
                    Expr::field("i", field),
                    Expr::array(array, vec![Expr::field("i", field)]),
                ],
            )],
        )
    }

    #[test]
    fn independent_counting_loops_reorder_and_fuse() {
        // The §III-A4 case: two counting loops over the same table on
        // different fields.
        let a = count("count1", "field1");
        let b = count("count2", "field2");
        assert!(can_reorder(&Stmt::Loop(a.clone()), &Stmt::Loop(b.clone())));
        assert!(can_fuse(&a, &b));
    }

    #[test]
    fn producer_consumer_cannot_fuse_or_reorder() {
        let w = count("count1", "field1");
        let r = reduce("count1", "field1");
        assert!(!can_reorder(&Stmt::Loop(w.clone()), &Stmt::Loop(r.clone())));
        // Different domains anyway (distinct vs all).
        assert!(!can_fuse(&w, &r));
    }

    #[test]
    fn counting_loop_can_jump_over_unrelated_reduce() {
        // count2's loop vs count1's reduce loop — the §III-A4 reordering.
        let c2 = count("count2", "field2");
        let r1 = reduce("count1", "field1");
        assert!(can_reorder(&Stmt::Loop(c2), &Stmt::Loop(r1)));
    }

    #[test]
    fn different_relations_do_not_fuse() {
        let a = count("c1", "f");
        let mut b = count("c2", "f");
        if let Domain::IndexSet(ix) = &mut b.domain {
            ix.relation = "U".into();
        }
        assert!(!can_fuse(&a, &b));
    }

    #[test]
    fn parallelizable_judgement() {
        assert!(is_parallelizable(&count("c", "f")));
        let mut l = count("c", "f");
        l.body.push(Stmt::assign("tmp", Expr::int(1)));
        assert!(!is_parallelizable(&l));
        let mut l2 = count("c", "f");
        l2.body = vec![Stmt::accum(
            "c",
            vec![Expr::field("i", "f")],
            AccumOp::Set,
            Expr::int(1),
        )];
        assert!(!is_parallelizable(&l2));
    }
}
