//! Def-Use analysis over the IR (§II: "Traditional analysis methods, such
//! as Def-Use analysis, will detect and eliminate data access of which the
//! results are unused, or will detect related data accesses that can be
//! combined.")
//!
//! Tracks, per statement, which accumulator arrays / result multisets /
//! scalars are *defined* (written) and *used* (read), plus which relation
//! fields are read — the input for dead-code elimination, dead-field
//! elimination (reformatting) and the fusion legality check.

use std::collections::BTreeSet;

use crate::ir::{Domain, Expr, Loop, Program, Stmt};

/// Read/write sets of a statement (or subtree).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DefUse {
    /// Arrays written (`count` in `count[x]++`).
    pub arrays_def: BTreeSet<String>,
    /// Arrays read.
    pub arrays_use: BTreeSet<String>,
    /// Result multisets appended to.
    pub results_def: BTreeSet<String>,
    /// Scalars written.
    pub scalars_def: BTreeSet<String>,
    /// Scalars/loop-vars read.
    pub scalars_use: BTreeSet<String>,
    /// Relation fields read, as `(relation-cursor-unresolved) field` pairs:
    /// `(relation, field)` once cursors are resolved via loop domains.
    pub fields_use: BTreeSet<(String, String)>,
    /// Relations iterated.
    pub relations_use: BTreeSet<String>,
}

impl DefUse {
    pub fn merge(&mut self, other: &DefUse) {
        self.arrays_def.extend(other.arrays_def.iter().cloned());
        self.arrays_use.extend(other.arrays_use.iter().cloned());
        self.results_def.extend(other.results_def.iter().cloned());
        self.scalars_def.extend(other.scalars_def.iter().cloned());
        self.scalars_use.extend(other.scalars_use.iter().cloned());
        self.fields_use.extend(other.fields_use.iter().cloned());
        self.relations_use.extend(other.relations_use.iter().cloned());
    }

    /// Do two statement subtrees conflict (write/write or read/write on any
    /// shared array, result or scalar)? Loops that do NOT conflict can be
    /// freely reordered — the §III-A4 statement-reordering legality test.
    pub fn conflicts_with(&self, other: &DefUse) -> bool {
        let ww = |a: &BTreeSet<String>, b: &BTreeSet<String>| a.intersection(b).next().is_some();
        ww(&self.arrays_def, &other.arrays_def)
            || ww(&self.arrays_def, &other.arrays_use)
            || ww(&self.arrays_use, &other.arrays_def)
            || ww(&self.results_def, &other.results_def)
            || ww(&self.scalars_def, &other.scalars_def)
            || ww(&self.scalars_def, &other.scalars_use)
            || ww(&self.scalars_use, &other.scalars_def)
    }
}

/// Compute def-use sets for one statement subtree.
///
/// `cursors` maps in-scope loop variables to the relation they iterate, so
/// `A[i].field` can be attributed to relation `A`.
pub fn stmt_defuse(s: &Stmt, cursors: &[(String, String)]) -> DefUse {
    let mut du = DefUse::default();
    collect(s, &mut cursors.to_vec(), &mut du);
    du
}

/// Def-use of a whole program body.
pub fn program_defuse(p: &Program) -> DefUse {
    let mut du = DefUse::default();
    let mut cursors = Vec::new();
    for s in &p.body {
        collect(s, &mut cursors, &mut du);
    }
    du
}

fn collect(s: &Stmt, cursors: &mut Vec<(String, String)>, du: &mut DefUse) {
    let use_expr = |e: &Expr, cursors: &[(String, String)], du: &mut DefUse| {
        e.walk(&mut |sub| match sub {
            Expr::Var(v) => {
                du.scalars_use.insert(v.clone());
            }
            Expr::Field { var, field } => {
                if let Some((_, rel)) = cursors.iter().rev().find(|(c, _)| c == var) {
                    du.fields_use.insert((rel.clone(), field.clone()));
                }
                du.scalars_use.insert(var.clone());
            }
            Expr::ArrayRef { array, .. } => {
                du.arrays_use.insert(array.clone());
            }
            _ => {}
        });
    };

    match s {
        Stmt::Loop(l) => {
            let rel = domain_relation(l);
            match &l.domain {
                Domain::IndexSet(ix) => {
                    du.relations_use.insert(ix.relation.clone());
                    if let Some((field, v)) = &ix.field_filter {
                        du.fields_use.insert((ix.relation.clone(), field.clone()));
                        use_expr(v, cursors, du);
                    }
                    if let Some(d) = &ix.distinct {
                        du.fields_use.insert((ix.relation.clone(), d.clone()));
                    }
                    if let Some(p) = &ix.partition {
                        use_expr(&p.part, cursors, du);
                        use_expr(&p.parts, cursors, du);
                    }
                }
                Domain::Range { lo, hi } => {
                    use_expr(lo, cursors, du);
                    use_expr(hi, cursors, du);
                }
                Domain::ValuePartition {
                    relation,
                    field,
                    part,
                    parts,
                } => {
                    du.relations_use.insert(relation.clone());
                    du.fields_use.insert((relation.clone(), field.clone()));
                    use_expr(part, cursors, du);
                    use_expr(parts, cursors, du);
                }
                Domain::DistinctValues { relation, field } => {
                    du.relations_use.insert(relation.clone());
                    du.fields_use.insert((relation.clone(), field.clone()));
                }
            }
            cursors.push((l.var.clone(), rel.unwrap_or_default()));
            for b in &l.body {
                collect(b, cursors, du);
            }
            cursors.pop();
        }
        Stmt::Accum {
            array,
            indices,
            value,
            ..
        } => {
            du.arrays_def.insert(array.clone());
            // An accumulation also reads the old value.
            du.arrays_use.insert(array.clone());
            for i in indices {
                use_expr(i, cursors, du);
            }
            use_expr(value, cursors, du);
        }
        Stmt::ResultUnion { result, tuple } => {
            du.results_def.insert(result.clone());
            for e in tuple {
                use_expr(e, cursors, du);
            }
        }
        Stmt::Assign { var, value } => {
            du.scalars_def.insert(var.clone());
            use_expr(value, cursors, du);
        }
        Stmt::If { cond, then, els } => {
            use_expr(cond, cursors, du);
            for b in then {
                collect(b, cursors, du);
            }
            for b in els {
                collect(b, cursors, du);
            }
        }
        Stmt::Print { args, .. } => {
            for a in args {
                use_expr(a, cursors, du);
            }
        }
    }
}

fn domain_relation(l: &Loop) -> Option<String> {
    match &l.domain {
        Domain::IndexSet(ix) => Some(ix.relation.clone()),
        Domain::DistinctValues { relation, .. } => Some(relation.clone()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Expr, IndexSet, Loop, Stmt};

    fn count_loop(array: &str, field: &str) -> Stmt {
        Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("T"),
            vec![Stmt::increment(array, vec![Expr::field("i", field)])],
        ))
    }

    #[test]
    fn accum_defines_and_uses_array() {
        let du = stmt_defuse(&count_loop("count", "url"), &[]);
        assert!(du.arrays_def.contains("count"));
        assert!(du.arrays_use.contains("count"));
        assert!(du.fields_use.contains(&("T".into(), "url".into())));
        assert!(du.relations_use.contains("T"));
    }

    #[test]
    fn independent_loops_do_not_conflict() {
        let a = stmt_defuse(&count_loop("c1", "f1"), &[]);
        let b = stmt_defuse(&count_loop("c2", "f2"), &[]);
        assert!(!a.conflicts_with(&b));
    }

    #[test]
    fn same_array_conflicts() {
        let a = stmt_defuse(&count_loop("c", "f1"), &[]);
        let b = stmt_defuse(&count_loop("c", "f2"), &[]);
        assert!(a.conflicts_with(&b));
    }

    #[test]
    fn reader_conflicts_with_writer() {
        let w = stmt_defuse(&count_loop("c", "f"), &[]);
        let r = stmt_defuse(
            &Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::distinct_of("T", "f"),
                vec![Stmt::result_union(
                    "R",
                    vec![Expr::array("c", vec![Expr::field("i", "f")])],
                )],
            )),
            &[],
        );
        assert!(w.conflicts_with(&r));
        // Two result writers to the same result also conflict (order matters
        // for bag semantics only if dedup'd; we stay conservative).
        assert!(r.conflicts_with(&r));
    }

    #[test]
    fn cursor_resolution_through_nesting() {
        let s = Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("A"),
            vec![Stmt::Loop(Loop::forelem(
                "j",
                IndexSet::filtered("B", "id", Expr::field("i", "b_id")),
                vec![Stmt::result_union(
                    "R",
                    vec![Expr::field("i", "x"), Expr::field("j", "y")],
                )],
            ))],
        ));
        let du = stmt_defuse(&s, &[]);
        assert!(du.fields_use.contains(&("A".into(), "x".into())));
        assert!(du.fields_use.contains(&("B".into(), "y".into())));
        assert!(du.fields_use.contains(&("A".into(), "b_id".into())));
        assert!(du.fields_use.contains(&("B".into(), "id".into())));
    }
}
