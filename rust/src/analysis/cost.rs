//! Cost model for index-set materialization and distribution decisions.
//!
//! The paper's compiler "determines how to actually execute the iteration
//! specified by a forelem loop and accompanied index set" (§II). This
//! model estimates the row-visit and build costs of each strategy given
//! table statistics, so materialization.rs can pick scan vs hash vs tree
//! the way Figure 1 shows.

use crate::ir::Strategy;

/// Statistics about one relation, supplied by the storage catalog.
#[derive(Debug, Clone, Copy)]
pub struct TableStats {
    /// Number of tuples.
    pub rows: u64,
    /// Distinct values of the candidate key field (1 if unknown).
    pub distinct_keys: u64,
}

impl TableStats {
    pub fn new(rows: u64, distinct_keys: u64) -> Self {
        TableStats {
            rows,
            distinct_keys: distinct_keys.max(1),
        }
    }

    /// Derive the legacy rows+NDV pair from the optimizer's full
    /// per-column statistics (`storage::ColumnStats`) — the cost
    /// functions below keep working unchanged while the estimator
    /// carries min/max/histograms on the side.
    pub fn from_column(stats: &crate::storage::ColumnStats) -> Self {
        TableStats::new(stats.rows, stats.ndv.min(stats.rows.max(1)))
    }
}

/// Rows a morsel fan-out must cover before parallel workers amortize
/// their spin-up (thread spawn + scheduler handshake + state merge).
/// Recalibrated to four `exec::BATCH` morsels: the SIMD-shaped batch
/// kernels (`exec/vector.rs`) raised sequential per-row throughput, so
/// the fixed spin-up cost now takes several batches to pay off instead
/// of one. The optimizer's fan-out gate (`opt::should_fan_out`)
/// consumes this.
pub const PARALLEL_SPINUP_ROWS: u64 = 4096;

/// Relative per-row cost constants (calibrated on the exec engine; see
/// EXPERIMENTS.md §Perf — only *ratios* matter for the decisions).
const SCAN_VISIT: f64 = 1.0;
const HASH_BUILD: f64 = 2.5;
const HASH_PROBE: f64 = 1.5;
const TREE_BUILD: f64 = 6.0;
const TREE_PROBE: f64 = 4.0;

/// Estimated cost of executing a filtered lookup `probes` times against a
/// table, under each strategy.
pub fn lookup_cost(strategy: Strategy, stats: TableStats, probes: u64) -> f64 {
    let rows = stats.rows as f64;
    let per_key = rows / stats.distinct_keys as f64; // expected matches/probe
    match strategy {
        // Every probe rescans the whole table.
        Strategy::Scan | Strategy::Unspecified => probes as f64 * rows * SCAN_VISIT,
        // Build once, then O(1 + matches) per probe.
        Strategy::Hash => rows * HASH_BUILD + probes as f64 * (HASH_PROBE + per_key),
        // Build once (sort), then O(log n + matches) per probe.
        Strategy::Tree => {
            rows * TREE_BUILD + probes as f64 * (TREE_PROBE * rows.log2().max(1.0) / 8.0 + per_key)
        }
    }
}

/// Pick the cheapest strategy for a filtered index set probed `probes`
/// times. `need_order` forces tree when ordered iteration is required.
pub fn choose_strategy(stats: TableStats, probes: u64, need_order: bool) -> Strategy {
    if need_order {
        return Strategy::Tree;
    }
    let candidates = [Strategy::Scan, Strategy::Hash, Strategy::Tree];
    *candidates
        .iter()
        .min_by(|a, b| {
            lookup_cost(**a, stats, probes)
                .partial_cmp(&lookup_cost(**b, stats, probes))
                .unwrap()
        })
        .unwrap()
}

/// Estimated rows visited by a full scan of a table (used by the
/// distribution optimizer to weigh redistribution against recompute).
pub fn scan_cost(stats: TableStats) -> f64 {
    stats.rows as f64 * SCAN_VISIT
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_probe_prefers_scan() {
        // One probe: building any index costs more than one scan.
        let stats = TableStats::new(10_000, 1_000);
        assert_eq!(choose_strategy(stats, 1, false), Strategy::Scan);
    }

    #[test]
    fn many_probes_prefer_hash() {
        // A join outer loop probing per tuple — the Figure-1 case.
        let stats = TableStats::new(10_000, 1_000);
        assert_eq!(choose_strategy(stats, 10_000, false), Strategy::Hash);
    }

    #[test]
    fn ordered_need_forces_tree() {
        let stats = TableStats::new(10_000, 1_000);
        assert_eq!(choose_strategy(stats, 10_000, true), Strategy::Tree);
    }

    #[test]
    fn hash_beats_scan_quadratic() {
        let stats = TableStats::new(100_000, 10_000);
        let scan = lookup_cost(Strategy::Scan, stats, 100_000);
        let hash = lookup_cost(Strategy::Hash, stats, 100_000);
        assert!(hash < scan / 100.0, "hash {hash} should crush scan {scan}");
    }

    #[test]
    fn crossover_exists() {
        // Somewhere between 1 probe and n probes the decision must flip.
        let stats = TableStats::new(10_000, 1_000);
        let mut flipped = false;
        let mut prev = choose_strategy(stats, 1, false);
        for probes in [2, 4, 8, 16, 64, 256, 1024, 8192] {
            let cur = choose_strategy(stats, probes, false);
            if cur != prev {
                flipped = true;
            }
            prev = cur;
        }
        assert!(flipped, "strategy never flipped with probe count");
    }
}
