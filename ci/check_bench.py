#!/usr/bin/env python3
"""Diff fresh BENCH_*.json records against the checked-in baselines.

Benches emit absolute medians *and* a headline speedup ratio. Absolute
nanoseconds are useless across heterogeneous CI runners, so the gate is
on the ratio, which is machine-independent to first order:

  * fail when a bench's speedup drops more than 30% below its baseline
    speedup (perf-trajectory regression), or
  * below the bench's hard floor (``min_speedup``, the acceptance bar
    stated in the bench's own PASS/FAIL line).

Baselines live in ci/bench_baselines/ and are hand-seeded conservatively;
refresh them from a CI bench-json artifact when a PR legitimately shifts
the trajectory.
"""

import glob
import json
import os
import sys

BASELINE_DIR = os.path.join("ci", "bench_baselines")
REGRESSION_FRACTION = 0.30


def main() -> int:
    records = sorted(glob.glob("BENCH_*.json"))
    if not records:
        print("no BENCH_*.json records found — run `cargo bench` first")
        return 1
    failed = False
    for path in records:
        base_path = os.path.join(BASELINE_DIR, os.path.basename(path))
        if not os.path.exists(base_path):
            print(f"{path}: no baseline checked in, skipping")
            continue
        with open(path) as f:
            current = json.load(f)
        with open(base_path) as f:
            baseline = json.load(f)
        floor = max(
            baseline["speedup"] * (1.0 - REGRESSION_FRACTION),
            baseline.get("min_speedup", 0.0),
        )
        ok = current["speedup"] >= floor
        status = "OK" if ok else "REGRESSION"
        print(
            f"{path}: speedup {current['speedup']:.2f}x "
            f"(baseline {baseline['speedup']:.2f}x, floor {floor:.2f}x) {status}"
        )
        if not ok:
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
