//! §III-B ablation: vertical integration — merged query+processing loop
//! vs staged query-then-process.
//!
//! The staged variant materializes the query's result multiset and then
//! folds it (what an application using a separate DBMS does); the merged
//! variant is the single forelem loop the compiler produces once query
//! and processing live in one intermediate.

use forelem::compiler::Engine;
use forelem::ir::{pretty, Expr, IndexSet, Loop, Program, Stmt, Value};
use forelem::storage::StorageCatalog;
use forelem::util::BenchTable;
use forelem::workload::grades;

fn main() {
    let students: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .map(|r: usize| r / 10)
        .unwrap_or(40_000);
    println!("# §III-B — vertical integration ({} grade rows)", students * 10);
    let data = grades(students, 10, 7);
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("Grades", &data).unwrap();
    let student = (students / 2) as i64;

    // Merged IR (what the compiler generates).
    let mut merged = Program::new("avg")
        .with_relation("Grades", data.schema.clone())
        .with_scalar("avg", Value::Float(0.0));
    merged.body = vec![Stmt::Loop(Loop::forelem(
        "i",
        IndexSet::filtered("Grades", "studentID", Expr::int(student)),
        vec![Stmt::assign(
            "avg",
            Expr::add(
                Expr::var("avg"),
                Expr::mul(Expr::field("i", "grade"), Expr::field("i", "weight")),
            ),
        )],
    ))];
    println!("{}", pretty::program(&merged));

    let mut engine = Engine::new(catalog.clone());
    let q = format!("SELECT grade, weight FROM Grades WHERE studentID = {student}");

    // Correctness tie: staged == merged.
    let staged_val: f64 = {
        let rows = engine.sql(&q).unwrap();
        rows.result()
            .unwrap()
            .rows()
            .iter()
            .map(|r| r[0].as_float().unwrap() * r[1].as_float().unwrap())
            .sum()
    };
    let merged_val = forelem::exec::run(&merged, &catalog).unwrap().scalars["avg"]
        .as_float()
        .unwrap();
    assert!((staged_val - merged_val).abs() < 1e-9);

    let mut t = BenchTable::new("weighted average of one student");
    t.row("staged: query → result set → fold", 1, 5, || {
        let rows = engine.sql(&q).unwrap();
        let v: f64 = rows
            .result()
            .unwrap()
            .rows()
            .iter()
            .map(|r| r[0].as_float().unwrap() * r[1].as_float().unwrap())
            .sum();
        v
    });
    t.row("merged: vertically integrated loop", 1, 5, || {
        forelem::exec::run(&merged, &catalog).unwrap()
    });
    t.summarize_vs("staged: query → result set → fold");

    // The paper's point scales with how much the query returns: repeat for
    // a query returning the WHOLE table (worst case for staging).
    let mut all_merged = merged.clone();
    if let Stmt::Loop(l) = &mut all_merged.body[0] {
        *l.index_set_mut().unwrap() = IndexSet::all("Grades");
    }
    let q_all = "SELECT grade, weight FROM Grades";
    let mut t = BenchTable::new("weighted average over ALL rows");
    t.row("staged (materializes everything)", 1, 3, || {
        let rows = engine.sql(q_all).unwrap();
        let v: f64 = rows
            .result()
            .unwrap()
            .rows()
            .iter()
            .map(|r| r[0].as_float().unwrap() * r[1].as_float().unwrap())
            .sum();
        v
    });
    t.row("merged (streams)", 1, 3, || {
        forelem::exec::run(&all_merged, &catalog).unwrap()
    });
    t.summarize_vs("staged (materializes everything)");
}
