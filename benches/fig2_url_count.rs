//! Figure 2, left group: URL access count — Hadoop vs forelem variants.
//!
//! Regenerates the paper's bars: hadoop / forelem-same-data /
//! forelem-integer-keyed (+XLA) / forelem-relayout. Absolute numbers
//! differ from DAS-4; the *shape* (who wins, roughly by how much, and
//! that relayout adds little beyond integer keying) is the claim under
//! test. Row count scales via BENCH_ROWS (default 500k to keep `cargo
//! bench` turnaround reasonable; EXPERIMENTS.md records the 2M run).

use std::sync::Arc;

use forelem::coordinator::{run_job, AggJob, ClusterConfig};
use forelem::exec::plan::KernelExec;
use forelem::mapreduce::{self, HadoopConfig, MapFn, MapReduceProgram, ReduceFn};
use forelem::runtime::Kernels;
use forelem::sched::Policy;
use forelem::storage::Table;
use forelem::util::BenchTable;
use forelem::workload::{access_log, AccessLogSpec};

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500_000);
    let urls = (rows / 20).max(100);
    let workers = 8;
    println!("# Figure 2 (URL access count): {rows} rows, {urls} URLs, {workers} workers");

    let m = access_log(&AccessLogSpec {
        rows,
        urls,
        skew: 1.1,
        seed: 42,
    });
    let table = Table::from_multiset(&m).unwrap();
    let mut keyed = table.clone();
    keyed.dict_encode_field(0).unwrap();
    let relayout = keyed.project(&[0]);
    let table = Arc::new(table);
    let keyed = Arc::new(keyed);
    let relayout = Arc::new(relayout);

    let mr = MapReduceProgram {
        map: MapFn::EmitKeyOne { key_field: 0 },
        reduce: ReduceFn::CountValues,
    };
    let cluster = ClusterConfig::new(workers, Policy::Gss);

    let mut t = BenchTable::new("URL access count");
    t.row("hadoop", 0, 2, || {
        mapreduce::run_hadoop(&HadoopConfig::default(), &mr, &table).unwrap()
    });
    t.row("forelem same-data (strings)", 1, 3, || {
        run_job(&cluster, &AggJob::count(table.clone(), 0)).unwrap()
    });
    t.row("forelem integer-keyed", 1, 5, || {
        run_job(&cluster, &AggJob::count(keyed.clone(), 0)).unwrap()
    });
    if let Ok(k) = Kernels::load_default() {
        let keys: Vec<i64> = keyed.column(0).as_int_keys().unwrap();
        let nk = keyed.column(0).dictionary().unwrap().len();
        if nk <= forelem::exec::plan::KERNEL_KEYSPACE {
            t.row("forelem integer-keyed via XLA", 1, 3, || {
                k.group_count(&keys, nk).unwrap()
            });
        }
    }
    t.row("forelem full relayout", 1, 5, || {
        run_job(&cluster, &AggJob::count(relayout.clone(), 0)).unwrap()
    });
    t.summarize_vs("hadoop");
}
