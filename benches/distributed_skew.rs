//! Perf baseline: salted repartitioning of a skew-heavy shuffle join.
//!
//! 60% of the probe rows share one join key. Plain hash partitioning
//! sends all of them to whichever node owns that key's hash — that node
//! grinds through ~60% of the work serially while the rest of the
//! cluster idles. Heavy-hitter salting (`dist.repartition_skew`) spreads
//! the hot key's probe rows across every node and replicates its (tiny)
//! build entry, so the schedule flattens back to ~rows/W per node.
//!
//! `row_cost` charges a fixed simulated cost per probe row, so the gap
//! measures the *schedule shape* (critical-path rows), not hash-map
//! noise — the result is deterministic across machines and core counts.
//!
//! Acceptance bar: salted repartitioning must be ≥ 2× plain hash
//! partitioning (expected ≈ 2.8× at W=4: 0.7·rows on the hot node vs
//! 0.25·rows per node salted). A faulted rerun (crash + straggler +
//! lost flush) must reproduce the exact same pairs. Row count scales
//! via BENCH_ROWS.

use std::collections::HashMap;
use std::time::Duration;

use forelem::coordinator::{run_shuffle_join, ClusterConfig, ShuffleJoinSpec};
use forelem::distrib::FaultPlan;
use forelem::ir::{DataType, Multiset, Schema, Value};
use forelem::sched::Policy;
use forelem::storage::Table;
use forelem::util::{fmt_duration, time_fn, write_bench_json};

const WORKERS: usize = 4;
const DIM_KEYS: i64 = 64;
const GROUPS: i64 = 9;

/// A fact with 60% of rows on key 0, the rest uniform over the
/// dimension's key domain, joined to a one-column dimension.
fn spec(rows: usize, repartition: bool) -> ShuffleJoinSpec {
    let fact_schema = Schema::new(vec![("k", DataType::Int), ("g", DataType::Int)]);
    let mut fact = Multiset::new(fact_schema);
    let hot = rows * 6 / 10;
    for i in 0..rows {
        let k = if i < hot { 0 } else { (i as i64) % DIM_KEYS };
        fact.push(vec![Value::Int(k), Value::Int((i as i64) % GROUPS)]);
    }
    let dim_schema = Schema::new(vec![("id", DataType::Int)]);
    let mut dim = Multiset::new(dim_schema);
    for k in 0..DIM_KEYS {
        dim.push(vec![Value::Int(k)]);
    }
    ShuffleJoinSpec {
        probe: Table::from_multiset(&fact).unwrap(),
        probe_key: "k".into(),
        build: Table::from_multiset(&dim).unwrap(),
        build_key: "id".into(),
        group_by: "g".into(),
        repartition,
    }
}

fn cluster() -> ClusterConfig {
    ClusterConfig::new(WORKERS, Policy::FixedChunk(512)).with_row_cost(Duration::from_nanos(400))
}

/// Sequential oracle: group counts of the joined rows.
fn oracle(s: &ShuffleJoinSpec) -> Vec<(Value, f64)> {
    let pk = s.probe.schema.field_id(&s.probe_key).unwrap();
    let bk = s.build.schema.field_id(&s.build_key).unwrap();
    let gb = s.probe.schema.field_id(&s.group_by).unwrap();
    let mut mult: HashMap<Value, f64> = HashMap::new();
    for r in 0..s.build.len() {
        *mult.entry(s.build.value(r, bk)).or_insert(0.0) += 1.0;
    }
    let mut acc: HashMap<Value, f64> = HashMap::new();
    for r in 0..s.probe.len() {
        if let Some(&m) = mult.get(&s.probe.value(r, pk)) {
            *acc.entry(s.probe.value(r, gb)).or_insert(0.0) += m;
        }
    }
    sorted(acc.into_iter().collect())
}

fn sorted(mut pairs: Vec<(Value, f64)>) -> Vec<(Value, f64)> {
    pairs.sort_by(|a, b| a.0.to_string().cmp(&b.0.to_string()));
    pairs
}

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    println!(
        "# Skewed shuffle join: {rows} probe rows (60% on one key), {DIM_KEYS} build keys, \
         {WORKERS} workers, 400ns/row simulated cost"
    );

    let plain = spec(rows, false);
    let salted = spec(rows, true);
    let cfg = cluster();
    let want = oracle(&plain);

    // Sanity before timing: both plans are exact, and only the salted
    // one reports the skew tag.
    let r_plain = run_shuffle_join(&cfg, &plain).unwrap();
    assert_eq!(sorted(r_plain.pairs.clone()), want, "plain hash plan diverged");
    assert!(
        !r_plain.metrics.tags.iter().any(|t| t == "dist.repartition_skew"),
        "repartition=false must not salt: {:?}",
        r_plain.metrics.tags
    );
    let r_salted = run_shuffle_join(&cfg, &salted).unwrap();
    assert_eq!(sorted(r_salted.pairs.clone()), want, "salted plan diverged");
    assert!(
        r_salted.metrics.tags.iter().any(|t| t == "dist.repartition_skew"),
        "the hot key must be detected and salted: {:?}",
        r_salted.metrics.tags
    );

    let plain_t = time_fn(1, 5, || run_shuffle_join(&cfg, &plain).unwrap());
    let salted_t = time_fn(1, 5, || run_shuffle_join(&cfg, &salted).unwrap());

    let mrows = rows as f64 / 1e6;
    let throughput = |d: Duration| mrows / d.as_secs_f64();
    println!(
        "plain hash partitioning (hot node serial)   {:>10}  {:>8.2} Mrows/s",
        fmt_duration(plain_t.median()),
        throughput(plain_t.median())
    );
    println!(
        "salted repartitioning   (hot key spread)    {:>10}  {:>8.2} Mrows/s",
        fmt_duration(salted_t.median()),
        throughput(salted_t.median())
    );

    let speedup = plain_t.median().as_secs_f64() / salted_t.median().as_secs_f64();
    println!(
        "skew-repartitioning speedup: {speedup:.1}x — {}",
        if speedup >= 2.0 {
            "PASS (>= 2x)"
        } else {
            "FAIL (< 2x acceptance bar)"
        }
    );

    // Resilience rerun: the same salted plan under a crash, a 6×
    // straggler, and a dropped flush still produces identical pairs.
    let faulted_cfg = cluster().with_faults(
        FaultPlan::none()
            .crash(2, 1)
            .slow(1, 6.0)
            .lose_flush(0, 0),
    );
    let r_faulted = run_shuffle_join(&faulted_cfg, &salted).unwrap();
    assert_eq!(
        sorted(r_faulted.pairs.clone()),
        want,
        "faulted run diverged: {}",
        r_faulted.metrics.render()
    );
    assert!(
        r_faulted.metrics.failures_recovered >= 1 && r_faulted.metrics.lost_flushes >= 1,
        "the injected faults must actually fire: {}",
        r_faulted.metrics.render()
    );
    println!(
        "faulted rerun (crash + straggler + lost flush): identical pairs; {}",
        r_faulted.metrics.render()
    );

    let path = write_bench_json(
        "distributed_skew",
        rows,
        &[
            ("plain-hash-partitioning", plain_t.median().as_nanos()),
            ("salted-repartitioning", salted_t.median().as_nanos()),
        ],
        speedup,
    )
    .unwrap();
    println!("wrote {}", path.display());
}
