//! Fused top-k heap kernel vs the strip-and-post-sort baseline.
//!
//! Before this kernel existed, `ORDER BY count DESC LIMIT k` never
//! reached the IR: the Engine stripped both clauses, materialized the
//! full aggregate, sorted it, and truncated. The bounded-heap `TopK`
//! accumulator (`vec.topk`) replaces that with an O(n log k) streaming
//! selection that retains only `k` rows.
//!
//! The bench aggregates a zipf-skewed URL table once (the §IV URL-count
//! workload), then times the two emission strategies over the resulting
//! (url, count) rows:
//!
//! * **strip-and-post-sort** — materialize all n aggregate rows, sort by
//!   count descending, truncate to k (exactly the deleted
//!   `Engine::apply_post` path);
//! * **fused topk heap** — stream the same rows through `TopK::bounded`.
//!
//! Acceptance bar: the fused kernel beats the baseline ≥ 2×; a PASS/FAIL
//! line is printed and the headline speedup lands in `BENCH_topk.json`
//! for the CI baseline diff (`ci/check_bench.py` fails on > 30%
//! regression or below `min_speedup`).
//!
//! Row count scales via BENCH_ROWS (number of URL-table rows; the
//! aggregate emits one row per distinct URL that appears).

use forelem::exec::{self, TopK};
use forelem::ir::Tuple;
use forelem::sql::compile_sql;
use forelem::storage::StorageCatalog;
use forelem::util::{fmt_duration, time_fn, write_bench_json};
use forelem::workload::{access_log, AccessLogSpec};

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let k = 10usize;
    // As many URLs as rows: the aggregate is wide (hundreds of thousands
    // of groups), which is where bounding the emission pays.
    let spec = AccessLogSpec {
        rows,
        urls: rows,
        skew: 1.1,
        seed: 23,
    };
    let m = access_log(&spec);
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("access", &m).unwrap();

    // Sanity: the ordered query is ONE program end-to-end and fires the
    // fused kernel on the vectorized tier.
    let ordered = compile_sql(
        "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n DESC LIMIT 10",
        &catalog.schemas(),
    )
    .unwrap();
    let out = exec::run_compiled(&ordered, &catalog, None).unwrap();
    assert_eq!(out.result().unwrap().len(), k);
    assert!(
        out.stats.idioms.contains(&"vec.topk".to_string()),
        "{:?}",
        out.stats.idioms
    );

    // The aggregate rows both emission strategies consume.
    let plain = compile_sql(
        "SELECT url, COUNT(url) AS n FROM access GROUP BY url",
        &catalog.schemas(),
    )
    .unwrap();
    let agg: Vec<Tuple> = exec::run_compiled(&plain, &catalog, None)
        .unwrap()
        .result()
        .unwrap()
        .rows()
        .to_vec();
    println!(
        "# Top-k emission: {rows} log rows -> {} aggregate rows, k = {k}",
        agg.len()
    );

    let baseline = || {
        // The deleted Engine path: materialize everything, sort, truncate.
        let mut v = agg.clone();
        v.sort_by(|a, b| {
            let ord = a[1].cmp(&b[1]);
            ord.reverse()
        });
        v.truncate(k);
        v
    };
    let fused = || {
        let mut tk = TopK::bounded(Some(1), true, k);
        for row in &agg {
            tk.push(row.clone());
        }
        tk.finish()
    };

    // The two strategies must select the same count prefix (ties are a
    // set; the count sequence is unique).
    let want: Vec<_> = baseline().iter().map(|r| r[1].clone()).collect();
    let got: Vec<_> = fused().iter().map(|r| r[1].clone()).collect();
    assert_eq!(want, got, "emission strategies disagree on the top-k counts");

    let nrows = agg.len() as f64 / 1e6;
    let baseline_t = time_fn(1, 5, baseline);
    let fused_t = time_fn(1, 5, fused);
    let throughput = |d: std::time::Duration| nrows / d.as_secs_f64();
    println!(
        "strip-and-post-sort (materialize+sort)  {:>10}  {:>8.2} Mrows/s",
        fmt_duration(baseline_t.median()),
        throughput(baseline_t.median())
    );
    println!(
        "fused topk heap (vec.topk, O(n log k))  {:>10}  {:>8.2} Mrows/s",
        fmt_duration(fused_t.median()),
        throughput(fused_t.median())
    );

    let speedup = baseline_t.median().as_secs_f64() / fused_t.median().as_secs_f64();
    println!(
        "fused heap speedup over strip-and-post-sort: {speedup:.1}x — {}",
        if speedup >= 2.0 {
            "PASS (>= 2x)"
        } else {
            "FAIL (< 2x acceptance bar)"
        }
    );

    let path = write_bench_json(
        "topk",
        rows,
        &[
            ("strip-and-post-sort", baseline_t.median().as_nanos()),
            ("fused-topk-heap", fused_t.median().as_nanos()),
        ],
        speedup,
    )
    .unwrap();
    println!("wrote {}", path.display());
}
