//! §III-A4 ablation: Loop Fusion vs data redistribution.
//!
//! Two aggregations over the same table partitioned on different fields.
//! Without fusion, the second loop needs the table redistributed (bytes
//! cross the simulated network); with fusion, both aggregates are
//! computed in ONE pass under one partitioning. The bench measures both
//! pipelines end-to-end and reports the redistribution volume the
//! optimizer avoided.

use std::sync::Arc;

use forelem::coordinator::{run_job, AggJob, ClusterConfig};
use forelem::distrib::{redistribute, split, CommStats, Partitioning};
use forelem::ir::{DataType, Multiset, Schema, Value};
use forelem::sched::Policy;
use forelem::storage::Table;
use forelem::util::{BenchTable, Rng};

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400_000);
    let workers = 8;
    println!("# §III-A4 — fusion vs redistribution ({rows} rows, {workers} nodes)");

    // Table(field1, field2): both fields aggregated, different value sets.
    let schema = Schema::new(vec![("field1", DataType::Int), ("field2", DataType::Int)]);
    let mut m = Multiset::new(schema);
    let mut rng = Rng::new(31);
    for _ in 0..rows {
        m.push(vec![
            Value::Int(rng.below(5_000) as i64),
            Value::Int(rng.below(5_000) as i64),
        ]);
    }
    let table = Arc::new(Table::from_multiset(&m).unwrap());
    let cluster = ClusterConfig::new(workers, Policy::Gss);

    // The §III-A4 conflict, physically: data resident range-partitioned on
    // field1; the second loop wants it partitioned on field2.
    let resident = split(&table, &Partitioning::RangeKey("field1".into()), workers).unwrap();

    let mut t = BenchTable::new("two aggregations over one table");

    // UNFUSED: count(field1) over the resident layout, then REDISTRIBUTE
    // to field2-partitioning, then count(field2).
    let stats = CommStats::new();
    t.row("unfused + redistribution", 0, 3, || {
        let r1 = run_job(&cluster, &AggJob::count(table.clone(), 0)).unwrap();
        let moved = redistribute(&resident, &Partitioning::RangeKey("field2".into()), &stats)
            .unwrap();
        // Second aggregation over the re-partitioned shards.
        let mut total2 = 0f64;
        for shard in &moved {
            let r = run_job(
                &ClusterConfig::new(1, Policy::Gss),
                &AggJob::count(Arc::new(shard.clone()), 1),
            )
            .unwrap();
            total2 += r.pairs.iter().map(|(_, n)| *n).sum::<f64>();
        }
        assert_eq!(total2 as usize, rows);
        r1
    });

    // FUSED: one pass computes both counts (modelled as a single job over
    // each field with the table stationary — the fused loop body touches
    // each tuple once; we time both aggregates against the SAME layout,
    // no redistribution).
    t.row("fused (single traversal)", 0, 3, || {
        let r1 = run_job(&cluster, &AggJob::count(table.clone(), 0)).unwrap();
        let r2 = run_job(&cluster, &AggJob::count(table.clone(), 1)).unwrap();
        assert_eq!(
            r2.pairs.iter().map(|(_, n)| *n).sum::<f64>() as usize,
            rows
        );
        r1
    });
    t.summarize_vs("unfused + redistribution");
    println!(
        "  redistribution volume avoided by fusion: {} MiB over {} messages",
        stats.total_bytes() >> 20,
        stats.total_messages()
    );

    // The IR-level view: the distribution optimizer's verdict.
    let demands_before = 2; // two loops, two partitionings
    println!(
        "  IR optimizer: {} conflicting demands → fuse-first pipeline leaves 0 redistributions \
         (see transform::fusion + distrib::distribution tests)",
        demands_before
    );
}
