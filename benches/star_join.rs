//! Perf baseline: the Selinger join-order DP on an N-way star join with
//! a *selective* dimension.
//!
//! The retail fact draws `product_id` from a domain 32× wider than the
//! `products` dimension (uniformly, so only ~1/32 of sales survive that
//! join), and the query is written in the worst order — small `customers`
//! first, so the written nest hashes the entire fact table and probes
//! `products` once per customers⋈sales match. The DP rewrites the chain
//! to `sales ⋈ products ⋈ customers`: the fact becomes the probe side,
//! the selective dimension filters first, and only survivors touch
//! `customers`. Both orders run on the same vectorized multi-level
//! hash-join kernel, so the measured gap is purely the plan choice.
//!
//! Acceptance bar: the DP-ordered plan must be ≥ 2× the written order.
//! Row count scales via BENCH_ROWS.

use forelem::exec;
use forelem::storage::StorageCatalog;
use forelem::util::{fmt_duration, time_fn, write_bench_json};
use forelem::workload::retail::{self, RetailSpec};

const QUERY: &str = "SELECT segment, COUNT(segment) FROM customers \
                     JOIN sales ON customers.id = sales.customer_id \
                     JOIN products ON sales.product_id = products.id \
                     GROUP BY segment";

fn spec(rows: usize) -> RetailSpec {
    RetailSpec {
        sales: rows,
        customers: (rows / 100).clamp(64, 4096),
        products: 256,
        stores: 16,
        categories: 8,
        // The selective-dimension shape: fact product ids span 32× the
        // dimension, drawn uniformly (skew 0), so ~1/32 of sales match.
        product_domain_factor: 32,
        skew: 0.0,
        seed: 42,
    }
}

fn catalog(rows: usize) -> StorageCatalog {
    let mut c = StorageCatalog::new();
    retail::register_retail(&mut c, &spec(rows)).unwrap();
    c
}

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    let s = spec(rows);
    println!(
        "# Selinger join order on a selective star: {} sales, {} customers, {} products (1/{} selective)",
        s.sales, s.customers, s.products, s.product_domain_factor
    );

    let c = catalog(rows);
    let written = forelem::sql::compile_sql(QUERY, &c.schemas()).unwrap();
    let mut ordered = written.clone();
    let report = forelem::opt::optimize(&mut ordered, &c).unwrap();
    let decision = report
        .decisions
        .iter()
        .find(|d| d.tag == "opt.join_order")
        .expect("the 3-table chain must reach the DP");
    assert!(
        decision.detail.contains("reordered from"),
        "the DP must beat the written order here: {}",
        decision.detail
    );
    println!("plan: [opt.join_order] {}", decision.detail);

    // Sanity before timing: both orders agree with each other at full
    // size, and with the interpreter at a reduced size (the written-order
    // interpreter is quadratic — unusable at 200k rows).
    let w_out = exec::run_compiled(&written, &c, None).unwrap();
    let o_out = exec::run_compiled(&ordered, &c, None).unwrap();
    assert!(
        w_out.result().unwrap().bag_eq(o_out.result().unwrap()),
        "reordered plan changed the result"
    );
    for out in [&w_out, &o_out] {
        assert!(
            out.stats.idioms.contains(&"vec.hash_join".to_string()),
            "both orders must run the vectorized chain: {:?}",
            out.stats.idioms
        );
    }
    let small = catalog(10_000.min(rows));
    let small_p = forelem::sql::compile_sql(QUERY, &small.schemas()).unwrap();
    let small_ref = exec::run(&small_p, &small).unwrap();
    let mut small_opt = small_p.clone();
    forelem::opt::optimize(&mut small_opt, &small).unwrap();
    let small_out = exec::run_compiled(&small_opt, &small, None).unwrap();
    assert!(
        small_out.result().unwrap().bag_eq(small_ref.result().unwrap()),
        "DP-ordered plan diverged from the interpreter"
    );

    let written_t = time_fn(1, 5, || exec::run_compiled(&written, &c, None).unwrap());
    let ordered_t = time_fn(1, 5, || exec::run_compiled(&ordered, &c, None).unwrap());

    let mrows = rows as f64 / 1e6;
    let throughput = |d: std::time::Duration| mrows / d.as_secs_f64();
    println!(
        "written order (customers ⋈ sales ⋈ products)  {:>10}  {:>8.2} Mrows/s",
        fmt_duration(written_t.median()),
        throughput(written_t.median())
    );
    println!(
        "DP order      (sales ⋈ products ⋈ customers)  {:>10}  {:>8.2} Mrows/s",
        fmt_duration(ordered_t.median()),
        throughput(ordered_t.median())
    );

    let speedup = written_t.median().as_secs_f64() / ordered_t.median().as_secs_f64();
    println!(
        "join-order speedup over the written nest: {speedup:.1}x — {}",
        if speedup >= 2.0 {
            "PASS (>= 2x)"
        } else {
            "FAIL (< 2x acceptance bar)"
        }
    );

    let path = write_bench_json(
        "star_join",
        rows,
        &[
            ("written-order-vectorized", written_t.median().as_nanos()),
            ("dp-order-vectorized", ordered_t.median().as_nanos()),
        ],
        speedup,
    )
    .unwrap();
    println!("wrote {}", path.display());
}
