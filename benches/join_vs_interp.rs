//! Perf baseline: the vectorized hash-join kernel vs the reference
//! interpreter on a Figure-1-style equi-join workload.
//!
//! The probe side is a zipfian-ish fact table `A(b_id, g)`; the build
//! side is a dimension table `B(id, w)` with one row per key, so the
//! join result has one match per probe row. Three baselines are
//! measured:
//!
//! * the interpreter on the program exactly as SQL lowering emits it
//!   (inner strategy unspecified → nested scans) — the acceptance bar is
//!   ≥ 3× over this;
//! * the interpreter with the inner loop forced to a cached hash index
//!   (the materialization pass's best case) — reported for context;
//! * the vectorized build+probe hash join, cold (compile + build each
//!   run) and with a pre-compiled program.
//!
//! A join + GROUP BY COUNT variant exercises the fused `vec.count`
//! per-match kernel. Row count scales via BENCH_ROWS.

use forelem::exec;
use forelem::exec::compile::compile_program;
use forelem::ir::{DataType, Multiset, Schema, Stmt, Strategy, Value};
use forelem::sql::compile_sql;
use forelem::storage::StorageCatalog;
use forelem::util::{fmt_duration, time_fn, write_bench_json, Rng};

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    let dim = (rows / 200).clamp(64, 4096);
    println!("# Hash join vs interpreter (Figure-1 equi-join): {rows} probe rows, {dim} build rows");

    let mut rng = Rng::new(42);
    let mut a = Multiset::new(Schema::new(vec![
        ("b_id", DataType::Int),
        ("g", DataType::Str),
    ]));
    for _ in 0..rows {
        a.push(vec![
            Value::Int(rng.range(0, dim as i64)),
            Value::str(format!("g{}", rng.below(64))),
        ]);
    }
    let mut b = Multiset::new(Schema::new(vec![
        ("id", DataType::Int),
        ("w", DataType::Float),
    ]));
    for i in 0..dim {
        b.push(vec![Value::Int(i as i64), Value::Float(rng.f64())]);
    }
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("A", &a).unwrap();
    catalog.insert_multiset("B", &b).unwrap();

    let join = compile_sql(
        "SELECT A.g, B.w FROM A JOIN B ON A.b_id = B.id",
        &catalog.schemas(),
    )
    .unwrap();
    // The interpreter's best case: inner loop probes a cached hash index.
    let mut join_hashed = join.clone();
    if let Stmt::Loop(outer) = &mut join_hashed.body[0] {
        if let Stmt::Loop(inner) = &mut outer.body[0] {
            inner.index_set_mut().unwrap().strategy = Strategy::Hash;
        }
    }

    // Sanity: all tiers agree before we time anything.
    let reference = exec::run(&join, &catalog).unwrap();
    let vectorized = exec::run_vectorized(&join, &catalog)
        .unwrap()
        .expect("vectorized tier must support the Figure-1 join");
    assert!(
        vectorized
            .result()
            .unwrap()
            .bag_eq(reference.result().unwrap()),
        "vectorized join diverged from the interpreter"
    );
    assert!(
        vectorized
            .stats
            .idioms
            .contains(&"vec.hash_join".to_string()),
        "hash-join kernel did not fire: {:?}",
        vectorized.stats.idioms
    );

    let interp = time_fn(0, 3, || exec::run(&join, &catalog).unwrap());
    let interp_hash = time_fn(1, 3, || exec::run(&join_hashed, &catalog).unwrap());
    let vector = time_fn(1, 5, || {
        exec::run_vectorized(&join, &catalog).unwrap().unwrap()
    });
    let cp = compile_program(&join, &catalog).expect("supported shape");
    let vector_precompiled = time_fn(1, 5, || exec::run_compiled_program(&cp).unwrap());

    let mrows = rows as f64 / 1e6;
    let throughput = |d: std::time::Duration| mrows / d.as_secs_f64();
    println!(
        "interpreter (as lowered)   {:>10}  {:>8.2} Mrows/s",
        fmt_duration(interp.median()),
        throughput(interp.median())
    );
    println!(
        "interpreter (hash index)   {:>10}  {:>8.2} Mrows/s",
        fmt_duration(interp_hash.median()),
        throughput(interp_hash.median())
    );
    println!(
        "vec.hash_join              {:>10}  {:>8.2} Mrows/s",
        fmt_duration(vector.median()),
        throughput(vector.median())
    );
    println!(
        "vec.hash_join (precomp)    {:>10}  {:>8.2} Mrows/s",
        fmt_duration(vector_precompiled.median()),
        throughput(vector_precompiled.median())
    );

    // Join + GROUP BY COUNT: the fused per-match kernel.
    let agg = compile_sql(
        "SELECT g, COUNT(g) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
        &catalog.schemas(),
    )
    .unwrap();
    let agg_ref = exec::run(&agg, &catalog).unwrap();
    let agg_vec = exec::run_vectorized(&agg, &catalog).unwrap().unwrap();
    assert!(agg_vec.result().unwrap().bag_eq(agg_ref.result().unwrap()));
    let agg_interp = time_fn(0, 3, || exec::run(&agg, &catalog).unwrap());
    let agg_vector = time_fn(1, 5, || {
        exec::run_vectorized(&agg, &catalog).unwrap().unwrap()
    });
    println!(
        "join+group-by interpreter  {:>10}  {:>8.2} Mrows/s",
        fmt_duration(agg_interp.median()),
        throughput(agg_interp.median())
    );
    println!(
        "join+group-by vec.count    {:>10}  {:>8.2} Mrows/s",
        fmt_duration(agg_vector.median()),
        throughput(agg_vector.median())
    );

    let speedup = interp.median().as_secs_f64() / vector.median().as_secs_f64();
    let hash_speedup = interp_hash.median().as_secs_f64() / vector.median().as_secs_f64();
    println!("vs hash-index interpreter: {hash_speedup:.1}x");
    println!(
        "hash-join speedup over interpreter: {speedup:.1}x — {}",
        if speedup >= 3.0 {
            "PASS (>= 3x)"
        } else {
            "FAIL (< 3x acceptance bar)"
        }
    );

    let path = write_bench_json(
        "join_vs_interp",
        rows,
        &[
            ("interpreter-as-lowered", interp.median().as_nanos()),
            ("interpreter-hash-index", interp_hash.median().as_nanos()),
            ("vec-hash-join", vector.median().as_nanos()),
            ("vec-hash-join-precompiled", vector_precompiled.median().as_nanos()),
            ("join-group-by-interpreter", agg_interp.median().as_nanos()),
            ("join-group-by-vec-count", agg_vector.median().as_nanos()),
        ],
        speedup,
    )
    .unwrap();
    println!("wrote {}", path.display());
}
