//! Serving-layer baseline: prepared plans, the plan cache, and the
//! shared multi-query morsel pool (`serve::Server`).
//!
//! Three measurements:
//!
//! * **cold compile vs cached plan** (the headline speedup) — planning
//!   the 3-way retail star query from scratch (parse, lower, Selinger
//!   join-order DP, transform pipeline) vs re-serving the same statement
//!   from the engine's plan cache. The tables are deliberately small so
//!   the number measures the compiler, not the scan. Acceptance bar:
//!   the cached plan must be ≥ 3× faster to obtain than a cold compile.
//! * **prepared-execution latency** — p50/p99 per-execution latency of
//!   one prepared scan+aggregate statement at 1, 4 and 16 concurrent
//!   clients multiplexed over a single 4-worker shared pool, bindings
//!   drawn mid-range so no execution re-plans.
//! * **16 concurrent vs 16 sequential** — wall-clock for 16 parameter
//!   bindings served concurrently through the shared pool vs the same 16
//!   queries as literal SQL through back-to-back `Engine::sql` calls
//!   (compile-per-query, single-threaded execution). Every concurrent
//!   result is checked `bag_eq`-identical to its sequential counterpart.
//!
//! Row count scales via BENCH_ROWS (the access-log table the prepared
//! statement scans).

use std::sync::Mutex;
use std::time::{Duration, Instant};

use forelem::compiler::Engine;
use forelem::ir::{Multiset, Value};
use forelem::serve::Server;
use forelem::storage::StorageCatalog;
use forelem::util::{fmt_duration, time_fn, write_bench_json};
use forelem::workload::retail::{self, RetailSpec};
use forelem::workload::{access_log_wide, AccessLogSpec};

/// Compile-heavy statement: a 3-way star join the Selinger DP reorders.
const STAR: &str = "SELECT segment, COUNT(segment) FROM customers \
                    JOIN sales ON customers.id = sales.customer_id \
                    JOIN products ON sales.product_id = products.id \
                    GROUP BY segment";

/// The prepared serving statement; `bytes` is uniform on [200, 100000).
const PREPARED: &str = "SELECT url, COUNT(*) FROM access WHERE bytes > ? GROUP BY url";

const WORKERS: usize = 4;
const MAX_INFLIGHT: usize = 8;
const PER_CLIENT: usize = 8;

fn percentile(sorted: &[Duration], p: f64) -> Duration {
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

fn access_catalog(m: &Multiset) -> StorageCatalog {
    let mut c = StorageCatalog::new();
    c.insert_multiset("access", m).unwrap();
    c
}

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);

    // ---- 1. cold compile vs cached plan ----------------------------
    // 2k fact rows: execution is trivial, so the cold/cached gap is the
    // compiler pipeline itself.
    let mut star_catalog = StorageCatalog::new();
    retail::register_retail(
        &mut star_catalog,
        &RetailSpec {
            sales: 2_000,
            ..RetailSpec::default()
        },
    )
    .unwrap();
    let mut eng = Engine::new(star_catalog);
    let sanity = eng.sql(STAR).unwrap();
    assert!(!sanity.result().unwrap().rows().is_empty());

    let cold = time_fn(2, 9, || eng.compile(STAR).unwrap());
    // Populate-then-hit: the warmup's first call seeds the cache at the
    // current statistics epoch, every timed call is a pure cache hit.
    let cached = time_fn(2, 9, || eng.plan(STAR).unwrap());
    let (_, hit) = eng.plan_cached(STAR).unwrap();
    assert!(hit, "cached timing loop must be served by the plan cache");

    println!("# Serving: star-query plan acquisition (2k-row retail catalog)");
    println!("cold compile (parse+optimize+transform)  {:>10}", fmt_duration(cold.median()));
    println!("cached plan (normalized-AST cache hit)   {:>10}", fmt_duration(cached.median()));
    let speedup = cold.median().as_secs_f64() / cached.median().as_secs_f64();
    println!(
        "cached-plan speedup over cold compile: {speedup:.1}x — {}",
        if speedup >= 3.0 {
            "PASS (>= 3x)"
        } else {
            "FAIL (< 3x acceptance bar)"
        }
    );

    // ---- 2. prepared-execution latency under concurrency -----------
    let m = access_log_wide(&AccessLogSpec {
        rows,
        urls: 500,
        skew: 1.1,
        seed: 47,
    });
    let srv = Server::new(Engine::new(access_catalog(&m)), WORKERS, MAX_INFLIGHT);
    let p = srv.prepare(PREPARED).unwrap();
    // Settle the rebind baseline mid-range: every measured binding stays
    // within REBIND_RATIO of it, so no execution re-enters the compiler.
    srv.execute(&p, &[Value::Int(50_000)]).unwrap();

    println!(
        "\n# Prepared `{PREPARED}` over {rows} rows, {WORKERS}-worker shared pool"
    );
    for &clients in &[1usize, 4, 16] {
        let latencies = Mutex::new(Vec::new());
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let (srv, p, latencies) = (&srv, &p, &latencies);
                scope.spawn(move || {
                    let mut mine = Vec::with_capacity(PER_CLIENT);
                    for i in 0..PER_CLIENT {
                        // Deterministic mid-range walk over [30000, 70000).
                        let bind = 30_000 + ((c * PER_CLIENT + i) * 977) % 40_000;
                        let q0 = Instant::now();
                        let out = srv.execute(p, &[Value::Int(bind as i64)]).unwrap();
                        mine.push(q0.elapsed());
                        assert!(
                            !out.stats.idioms.iter().any(|t| t == "opt.rebind"),
                            "mid-range bindings must not re-plan"
                        );
                    }
                    latencies.lock().unwrap().extend(mine);
                });
            }
        });
        let wall = t0.elapsed();
        let mut v = latencies.into_inner().unwrap();
        v.sort();
        println!(
            "{clients:>2} clients  {:>3} execs  p50 {:>10}  p99 {:>10}  wall {:>10}",
            v.len(),
            fmt_duration(percentile(&v, 0.50)),
            fmt_duration(percentile(&v, 0.99)),
            fmt_duration(wall)
        );
    }

    // ---- 3. 16 concurrent prepared vs 16 sequential Engine::sql ----
    let thresholds: Vec<i64> = (0..16).map(|i| 30_000 + 2_500 * i).collect();

    let mut seq_eng = Engine::new(access_catalog(&m));
    let t0 = Instant::now();
    let seq_outs: Vec<_> = thresholds
        .iter()
        .map(|t| {
            seq_eng
                .sql(&format!(
                    "SELECT url, COUNT(*) FROM access WHERE bytes > {t} GROUP BY url"
                ))
                .unwrap()
        })
        .collect();
    let wall_seq = t0.elapsed();

    let t0 = Instant::now();
    let conc_outs: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = thresholds
            .iter()
            .map(|&t| {
                let (srv, p) = (&srv, &p);
                scope.spawn(move || srv.execute(p, &[Value::Int(t)]).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall_conc = t0.elapsed();

    for ((t, seq), conc) in thresholds.iter().zip(&seq_outs).zip(&conc_outs) {
        assert!(
            conc.result().unwrap().bag_eq(seq.result().unwrap()),
            "threshold {t}: concurrent serving diverged from sequential Engine::sql"
        );
    }
    assert!(conc_outs[0].stats.idioms.iter().any(|t| t == "serve.admit"));

    println!("\n# 16 bindings: shared-pool concurrent vs sequential literal SQL");
    println!("sequential Engine::sql (compile each)    {:>10}", fmt_duration(wall_seq));
    println!("concurrent serve::Server (prepare once)  {:>10}", fmt_duration(wall_conc));
    let conc_speedup = wall_seq.as_secs_f64() / wall_conc.as_secs_f64();
    println!(
        "concurrent serving speedup: {conc_speedup:.1}x — {}",
        if conc_speedup > 1.0 {
            "PASS (beats sequential)"
        } else {
            "FAIL (no faster than sequential)"
        }
    );

    let path = write_bench_json(
        "serving",
        rows,
        &[
            ("cold-compile", cold.median().as_nanos()),
            ("cached-plan", cached.median().as_nanos()),
            ("sequential-16", wall_seq.as_nanos()),
            ("concurrent-16", wall_conc.as_nanos()),
        ],
        speedup,
    )
    .unwrap();
    println!("wrote {}", path.display());
}
