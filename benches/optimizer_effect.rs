//! Perf effect of the cost-based optimizer's join build-side choice.
//!
//! A skewed equi-join where the build side matters: the small `dim`
//! table is written where SQL lowering would make the big `fact` table
//! the hash-join build side. The optimizer (`opt::optimize`) must swap
//! the Figure-1 nest so the vectorized tier hashes `dim` (a few hundred
//! entries) and probes with `fact` (hundreds of thousands of rows, most
//! probes missing) instead of building a `fact`-sized hash table per
//! run. Acceptance bar: the optimized plan beats the unoptimized plan
//! ≥ 2×; a PASS/FAIL line is printed and the headline speedup lands in
//! `BENCH_optimizer_effect.json` for the CI baseline diff
//! (`ci/check_bench.py` fails on > 30% regression).
//!
//! Row count scales via BENCH_ROWS (fact rows).

use forelem::exec;
use forelem::exec::compile::{compile_program, CStmt};
use forelem::ir::{DataType, Multiset, Schema, Value};
use forelem::sql::compile_sql;
use forelem::storage::StorageCatalog;
use forelem::util::{fmt_duration, time_fn, write_bench_json, Rng};

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400_000);
    let dim_rows = 512;
    // Most fact keys miss the dim table: the unoptimized plan still pays
    // to hash every fact row, while the optimized plan's probes miss
    // cheaply.
    let keyspace = (rows / 4).max(dim_rows * 4) as i64;
    println!(
        "# Optimizer effect (join build side): {rows} fact rows, {dim_rows} dim rows, \
         key space {keyspace}"
    );

    let mut rng = Rng::new(77);
    let mut dim = Multiset::new(Schema::new(vec![
        ("id", DataType::Int),
        ("g", DataType::Str),
    ]));
    for i in 0..dim_rows as i64 {
        dim.push(vec![Value::Int(i), Value::str(format!("g{}", i % 32))]);
    }
    let mut fact = Multiset::new(Schema::new(vec![
        ("a_id", DataType::Int),
        ("w", DataType::Int),
    ]));
    for _ in 0..rows {
        fact.push(vec![
            Value::Int(rng.range(0, keyspace)),
            Value::Int(rng.range(0, 100)),
        ]);
    }
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("dim", &dim).unwrap();
    catalog.insert_multiset("fact", &fact).unwrap();

    // Small build side written FIRST: as lowered, the nest hashes `fact`.
    let q = "SELECT g, COUNT(g) FROM dim JOIN fact ON dim.id = fact.a_id GROUP BY g";
    let unopt = compile_sql(q, &catalog.schemas()).unwrap();
    let mut opt = unopt.clone();
    let report = forelem::opt::optimize(&mut opt, &catalog).unwrap();
    assert!(
        report.has("opt.join_build_side"),
        "optimizer must decide the build side: {report:?}"
    );

    // Sanity before timing: the swap actually moved the build side, the
    // hash-join kernel fires on both plans, and the results agree.
    let cp_unopt = compile_program(&unopt, &catalog).expect("join shape");
    let cp_opt = compile_program(&opt, &catalog).expect("swapped join shape");
    let build_of = |cp: &forelem::exec::CompiledProgram| match &cp.body[0] {
        CStmt::Join(j) => j.build.len(),
        other => panic!("expected a compiled join, got {other:?}"),
    };
    assert_eq!(build_of(&cp_unopt), rows, "unoptimized plan builds on fact");
    assert_eq!(build_of(&cp_opt), dim_rows, "optimized plan builds on dim");
    let out_unopt = exec::run_vectorized(&unopt, &catalog).unwrap().unwrap();
    let out_opt = exec::run_vectorized(&opt, &catalog).unwrap().unwrap();
    assert!(
        out_unopt
            .result()
            .unwrap()
            .bag_eq(out_opt.result().unwrap()),
        "optimized plan changed the results"
    );
    for out in [&out_unopt, &out_opt] {
        assert!(
            out.stats.idioms.contains(&"vec.hash_join".to_string()),
            "{:?}",
            out.stats.idioms
        );
    }
    assert!(
        out_opt
            .stats
            .idioms
            .contains(&"opt.join_build_side".to_string()),
        "{:?}",
        out_opt.stats.idioms
    );

    let mrows = rows as f64 / 1e6;
    let throughput = |d: std::time::Duration| mrows / d.as_secs_f64();
    let unopt_t = time_fn(1, 5, || {
        exec::run_vectorized(&unopt, &catalog).unwrap().unwrap()
    });
    let opt_t = time_fn(1, 5, || exec::run_vectorized(&opt, &catalog).unwrap().unwrap());
    println!(
        "vec.hash_join (build=fact, as written)  {:>10}  {:>8.2} Mrows/s",
        fmt_duration(unopt_t.median()),
        throughput(unopt_t.median())
    );
    println!(
        "vec.hash_join (build=dim, optimized)    {:>10}  {:>8.2} Mrows/s",
        fmt_duration(opt_t.median()),
        throughput(opt_t.median())
    );

    let speedup = unopt_t.median().as_secs_f64() / opt_t.median().as_secs_f64();
    println!(
        "optimizer speedup over the unswapped plan: {speedup:.1}x — {}",
        if speedup >= 2.0 {
            "PASS (>= 2x)"
        } else {
            "FAIL (< 2x acceptance bar)"
        }
    );

    let path = write_bench_json(
        "optimizer_effect",
        rows,
        &[
            ("vec-join-build-fact-unoptimized", unopt_t.median().as_nanos()),
            ("vec-join-build-dim-optimized", opt_t.median().as_nanos()),
        ],
        speedup,
    )
    .unwrap();
    println!("wrote {}", path.display());
}
