//! L1/L2 offload bench: the integer-keyed counting hot loop as (a) the
//! native Rust loop, (b) the AOT-compiled XLA scatter artifact (L2), and
//! (c) the AOT-compiled Pallas one-hot artifact (L1, interpret-mode —
//! structure is TPU-shaped, timing is CPU; see DESIGN.md
//! §Hardware-Adaptation).
//!
//! Checks all three agree exactly, then times them at the artifact sizes.

use forelem::exec::plan::KernelExec;
use forelem::runtime::Kernels;
use forelem::util::{BenchTable, Rng, Zipf};

fn main() {
    let Ok(mut kernels) = Kernels::load_default() else {
        println!("# kernel_offload: artifacts not built (run `make artifacts`); skipping");
        return;
    };
    println!("# L1/L2 kernel offload — count-by-key");

    for (n, k) in [(65_536, 1024), (262_144, 1024), (262_144, 131_072)] {
        let mut rng = Rng::new(77);
        let zipf = Zipf::new(k, 1.1);
        let keys: Vec<i64> = (0..n).map(|_| zipf.sample(&mut rng) as i64).collect();

        // Native reference.
        let native = |keys: &[i64]| {
            let mut counts = vec![0i64; k];
            for &key in keys {
                counts[key as usize] += 1;
            }
            counts
        };
        let want = native(&keys);

        kernels.prefer_onehot = false;
        let scatter = kernels.group_count(&keys, k).unwrap();
        assert_eq!(scatter, want, "scatter artifact diverges");
        let has_onehot = k <= 1024;
        if has_onehot {
            kernels.prefer_onehot = true;
            let onehot = kernels.group_count(&keys, k).unwrap();
            assert_eq!(onehot, want, "one-hot artifact diverges");
        }

        let mut t = BenchTable::new(&format!("n={n} keys, key-space={k}"));
        t.row("native rust loop", 1, 5, || native(&keys));
        kernels.prefer_onehot = false;
        t.row("XLA scatter artifact (L2)", 1, 3, || {
            kernels.group_count(&keys, k).unwrap()
        });
        if has_onehot {
            kernels.prefer_onehot = true;
            t.row("XLA pallas one-hot artifact (L1)", 1, 2, || {
                kernels.group_count(&keys, k).unwrap()
            });
        }
        t.summarize_vs("native rust loop");
    }
    println!(
        "\n  note: the one-hot kernel does O(n*K) work by design (MXU contraction form);\n  \
         on CPU-interpret it trails the O(n) scatter — on a real MXU the contraction\n  \
         is the winning shape for modest K. See DESIGN.md §Hardware-Adaptation."
    );
}
