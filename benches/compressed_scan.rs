//! Compressed-domain execution vs decode-up-front.
//!
//! The vectorized tier runs equality filters and fused aggregations
//! directly on compressed storage: string filters resolve once against
//! the dictionary and compare u32 codes (`vec.dict_filter`), filters
//! over RLE integer columns compare once per run and emit whole runs
//! (`vec.rle_filter`), and fused group-by aggregations multiply by run
//! length with one accumulator probe per run (`vec.rle_agg`). The
//! alternative strategy — what `opt.compressed_scan` decides against —
//! is to decode the compressed columns back to flat values up front and
//! run the same queries over the raw layout.
//!
//! The bench builds one table (dict-encoded url column, RLE status-code
//! column, plain int payload), runs a dict filter + an RLE filter + a
//! fused RLE group-by on the vectorized tier, and times the
//! compressed-domain path against decode-up-front (decode included in
//! the timing: that is the cost the in-place kernels avoid).
//!
//! Acceptance bar: compressed-domain beats decode-up-front ≥ 2×; a
//! PASS/FAIL line is printed and the headline speedup lands in
//! `BENCH_compressed_scan.json` for the CI baseline diff
//! (`ci/check_bench.py`).
//!
//! Row count scales via BENCH_ROWS.

use forelem::exec;
use forelem::ir::{DataType, Multiset, Schema, Value};
use forelem::sql::compile_sql;
use forelem::storage::{Column, StorageCatalog, Table};
use forelem::util::{fmt_duration, time_fn, write_bench_json};

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    // Enough distinct urls that the dict filter is selective, and runs
    // long enough that the RLE layout clears the compressor's 2x bar.
    let urls = 4096usize;
    let run = 512usize;
    let codes = 1009i64;

    let mut m = Multiset::new(Schema::new(vec![
        ("url", DataType::Str),
        ("code", DataType::Int),
        ("n", DataType::Int),
    ]));
    for i in 0..rows {
        m.push(vec![
            Value::str(format!("/u{}", i % urls)),
            Value::Int((i / run) as i64 % codes),
            Value::Int((i % 13) as i64),
        ]);
    }
    let mut t = Table::from_multiset(&m).unwrap();
    t.dict_encode_field(0).unwrap();
    assert!(t.compress_int_field(1).unwrap(), "code column must compress");
    let mut packed = StorageCatalog::new();
    packed.insert("t", t);
    let packed_t = packed.get("t").unwrap().clone();
    println!(
        "# Compressed scan: {rows} rows — url {}, code {}",
        packed_t.column(0).scheme(),
        packed_t.column(1).scheme()
    );

    let queries = [
        "SELECT n FROM t WHERE url = '/u3'",
        "SELECT n FROM t WHERE code = 300",
        "SELECT code, SUM(n) FROM t GROUP BY code",
    ];
    let programs: Vec<_> = queries
        .iter()
        .map(|q| compile_sql(q, &packed.schemas()).unwrap())
        .collect();

    // Sanity: the compressed-domain kernels actually fire in place.
    let tags = ["vec.dict_filter", "vec.rle_filter", "vec.rle_agg"];
    for (p, tag) in programs.iter().zip(tags) {
        let out = exec::run_vectorized(p, &packed)
            .unwrap()
            .expect("vectorized tier must take these shapes");
        assert!(
            out.stats.idioms.contains(&tag.to_string()),
            "missing {tag}: {:?}",
            out.stats.idioms
        );
    }

    // Decode-up-front: materialize raw columns (dict keys back to
    // strings, RLE back to a flat i64 vector) before executing.
    let decode = |t: &Table| -> Table {
        let columns = t
            .columns
            .iter()
            .map(|c| match c {
                Column::DictStrs { keys, dict } => Column::Strs(
                    keys.iter()
                        .map(|&k| dict.decode(k).expect("key in range").clone())
                        .collect(),
                ),
                Column::CompressedInts(ci) => Column::Ints(ci.decompress()),
                other => other.clone(),
            })
            .collect();
        Table::new(t.schema.clone(), columns).unwrap()
    };

    let run_all = |catalog: &StorageCatalog| -> usize {
        programs
            .iter()
            .map(|p| {
                exec::run_vectorized(p, catalog)
                    .unwrap()
                    .expect("vectorized tier must take these shapes")
                    .result()
                    .unwrap()
                    .len()
            })
            .sum()
    };

    // Both strategies must agree bag-for-bag on every query.
    {
        let mut c = StorageCatalog::new();
        c.insert("t", decode(&packed_t));
        for (p, q) in programs.iter().zip(queries) {
            let a = exec::run_vectorized(p, &packed).unwrap().unwrap();
            let b = exec::run_vectorized(p, &c).unwrap().unwrap();
            assert!(
                a.result().unwrap().bag_eq(b.result().unwrap()),
                "`{q}`: compressed-domain and decoded results disagree"
            );
        }
    }

    let compressed = || run_all(&packed);
    let decoded = || {
        let mut c = StorageCatalog::new();
        c.insert("t", decode(&packed_t));
        run_all(&c)
    };

    let nrows = rows as f64 / 1e6;
    let decoded_t = time_fn(1, 5, decoded);
    let compressed_t = time_fn(1, 5, compressed);
    let throughput = |d: std::time::Duration| nrows / d.as_secs_f64();
    println!(
        "decode-up-front (materialize raw, then scan)  {:>10}  {:>8.2} Mrows/s",
        fmt_duration(decoded_t.median()),
        throughput(decoded_t.median())
    );
    println!(
        "compressed-domain (dict codes + RLE runs)     {:>10}  {:>8.2} Mrows/s",
        fmt_duration(compressed_t.median()),
        throughput(compressed_t.median())
    );

    let speedup = decoded_t.median().as_secs_f64() / compressed_t.median().as_secs_f64();
    println!(
        "compressed-domain speedup over decode-up-front: {speedup:.1}x — {}",
        if speedup >= 2.0 {
            "PASS (>= 2x)"
        } else {
            "FAIL (< 2x acceptance bar)"
        }
    );

    let path = write_bench_json(
        "compressed_scan",
        rows,
        &[
            ("decode-up-front", decoded_t.median().as_nanos()),
            ("compressed-domain", compressed_t.median().as_nanos()),
        ],
        speedup,
    )
    .unwrap();
    println!("wrote {}", path.display());
}
