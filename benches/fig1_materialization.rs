//! Figure 1: one forelem join spec, different generated evaluation
//! schemes (nested-loops scan vs hash index vs tree index).
//!
//! The paper's point: the IR fixes *what* to iterate; the compiler picks
//! *how* late, from table statistics. This bench regenerates the
//! comparison and shows where the cost model's crossover lies.

use forelem::analysis::{choose_strategy, TableStats};
use forelem::compiler::Engine;
use forelem::prelude::*;
use forelem::storage::StorageCatalog;
use forelem::util::BenchTable;

fn catalog(rows_a: usize, rows_b: usize, keys: usize) -> StorageCatalog {
    let mut c = StorageCatalog::new();
    let mut a = Multiset::new(Schema::new(vec![
        ("b_id", DataType::Int),
        ("field", DataType::Str),
    ]));
    for i in 0..rows_a as i64 {
        a.push(vec![Value::Int(i % keys as i64), Value::str(format!("a{i}"))]);
    }
    let mut b = Multiset::new(Schema::new(vec![
        ("id", DataType::Int),
        ("field", DataType::Str),
    ]));
    for i in 0..rows_b as i64 {
        b.push(vec![Value::Int(i % keys as i64), Value::str(format!("b{i}"))]);
    }
    c.insert_multiset("A", &a).unwrap();
    c.insert_multiset("B", &b).unwrap();
    c
}

fn with_strategy(p: &Program, s: Strategy) -> Program {
    let mut p = p.clone();
    if let Stmt::Loop(outer) = &mut p.body[0] {
        if let Stmt::Loop(inner) = &mut outer.body[0] {
            inner.index_set_mut().unwrap().strategy = s;
        }
    }
    p
}

fn main() {
    println!("# Figure 1 — index-set materialization schemes for the same join spec");
    for (rows, keys) in [(2_000, 500), (20_000, 2_000), (60_000, 5_000)] {
        let catalog = catalog(rows, keys * 2, keys);
        let mut engine = Engine::new(catalog);
        let compiled = engine
            .compile("SELECT A.field, B.field FROM A JOIN B ON A.b_id = B.id")
            .unwrap();
        let mut table = BenchTable::new(&format!("join |A|={rows}, |B|={}, keys={keys}", keys * 2));
        let reference = forelem::exec::run(
            &with_strategy(&compiled.program, Strategy::Hash),
            &engine.catalog,
        )
        .unwrap()
        .result()
        .unwrap()
        .clone();
        for strat in [Strategy::Scan, Strategy::Hash, Strategy::Tree] {
            let p = with_strategy(&compiled.program, strat);
            let catalog = &engine.catalog;
            // Verify once, then time.
            let out = forelem::exec::run(&p, catalog).unwrap();
            assert!(out.result().unwrap().bag_eq(&reference), "{strat} wrong");
            table.row(
                &format!("{strat}"),
                1,
                if rows > 20_000 && strat == Strategy::Scan { 1 } else { 3 },
                || forelem::exec::run(&p, catalog).unwrap(),
            );
        }
        table.summarize_vs("scan");
        // What the cost model itself picks at this size:
        let stats = engine.catalog.stats("B", Some(0)).unwrap();
        println!(
            "  cost model chooses: {} (stats: rows={}, distinct={})",
            choose_strategy(stats, rows as u64, false),
            stats.rows,
            stats.distinct_keys
        );
        // And the crossover: probes at which an index starts to win.
        let crossover = (0..=20)
            .map(|e| 1u64 << e)
            .find(|&probes| {
                choose_strategy(TableStats::new((keys * 2) as u64, keys as u64), probes, false)
                    != Strategy::Scan
            });
        println!("  scan→index crossover at ~{crossover:?} probes");
    }
}
