//! Perf baseline: SIMD-shaped batch kernels vs scalar-reference loops.
//!
//! Two microbenches over flat columns, isolating the inner loops the
//! vectorized tier runs under its `vec.simd` tag:
//!
//! * **equality filter** — branchless `chunks_exact(LANES)` selection
//!   building (`select_eq_i64`) vs the obvious branchy
//!   `if v == key { sel.push(i) }` loop, at ~1/3 selectivity where the
//!   branch is hardest to predict;
//! * **group-by sum** — lane-striped dense accumulation
//!   (`sum_batch_u32_i64_striped` + `fold_lanes_i64`) vs the scalar
//!   `acc[k] += v` loop, with 90% of rows on one hot key so the scalar
//!   loop serializes on its store-to-load dependence.
//!
//! The acceptance bar is ≥ 1.5× on *both* microbenches (the headline
//! speedup is the minimum of the two); the run prints a PASS/FAIL line
//! and emits `BENCH_simd_kernels.json` for the CI perf-trajectory
//! artifact. Row count scales via BENCH_ROWS.

use forelem::exec::{
    fold_lanes_i64, select_eq_i64, sum_batch_u32_i64_striped, LANES, MAX_STRIPED_WIDTH,
};
use forelem::util::{fmt_duration, time_fn, write_bench_json, Rng};

/// The branchy loop `select_eq_i64` replaces: push each matching index.
fn select_eq_scalar(vals: &[i64], key: i64, base: usize, sel: &mut Vec<usize>) {
    for (i, &v) in vals.iter().enumerate() {
        if v == key {
            sel.push(base + i);
        }
    }
}

/// The scalar dense group-by sum the striped kernel replaces.
fn sum_group_scalar(keys: &[u32], vals: &[i64], acc: &mut [i64]) {
    for (&k, &v) in keys.iter().zip(vals) {
        acc[k as usize] = acc[k as usize].wrapping_add(v);
    }
}

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000_000);
    let width = 64usize;
    assert!(width <= MAX_STRIPED_WIDTH);
    println!(
        "# SIMD-shaped batch kernels vs scalar reference: {rows} rows, LANES={LANES}, \
         {width} groups (90% hot-key skew)"
    );

    let mut rng = Rng::new(7);
    let vals: Vec<i64> = (0..rows).map(|_| rng.below(3) as i64).collect();
    let keys: Vec<u32> = (0..rows)
        .map(|_| {
            if rng.below(10) < 9 {
                0
            } else {
                rng.below(width as u64) as u32
            }
        })
        .collect();
    let sums: Vec<i64> = (0..rows).map(|_| rng.range(-1000, 1000)).collect();

    // Sanity: both shapes produce identical results before timing them.
    let mut want_sel = Vec::new();
    select_eq_scalar(&vals, 1, 0, &mut want_sel);
    let mut got_sel = Vec::new();
    select_eq_i64(&vals, 1, 0, &mut got_sel);
    assert_eq!(want_sel, got_sel, "branchless selection diverged from the branchy loop");
    let mut want_acc = vec![0i64; width];
    sum_group_scalar(&keys, &sums, &mut want_acc);
    let mut stripes = vec![0i64; LANES * width];
    sum_batch_u32_i64_striped(&keys, &sums, width, &mut stripes);
    assert_eq!(want_acc, fold_lanes_i64(width, &stripes), "striped sum diverged from scalar");

    let mut sel = Vec::with_capacity(rows);
    let filt_scalar = time_fn(2, 9, || {
        sel.clear();
        select_eq_scalar(&vals, 1, 0, &mut sel);
        sel.len()
    });
    let mut sel = Vec::with_capacity(rows);
    let filt_simd = time_fn(2, 9, || {
        sel.clear();
        select_eq_i64(&vals, 1, 0, &mut sel);
        sel.len()
    });

    let mut acc = vec![0i64; width];
    let sum_scalar = time_fn(2, 9, || {
        acc.iter_mut().for_each(|a| *a = 0);
        sum_group_scalar(&keys, &sums, &mut acc);
        acc[0]
    });
    let mut stripes = vec![0i64; LANES * width];
    let sum_striped = time_fn(2, 9, || {
        stripes.iter_mut().for_each(|s| *s = 0);
        sum_batch_u32_i64_striped(&keys, &sums, width, &mut stripes);
        fold_lanes_i64(width, &stripes)[0]
    });

    let mrows = rows as f64 / 1e6;
    let throughput = |d: std::time::Duration| mrows / d.as_secs_f64();
    let report = |name: &str, s: &forelem::util::Stats| {
        println!(
            "{name:<24} {:>10}  {:>8.2} Mrows/s",
            fmt_duration(s.median()),
            throughput(s.median())
        );
    };
    report("filter scalar", &filt_scalar);
    report("filter simd-shaped", &filt_simd);
    report("group-sum scalar", &sum_scalar);
    report("group-sum striped", &sum_striped);

    let filt_speedup = filt_scalar.median().as_secs_f64() / filt_simd.median().as_secs_f64();
    let sum_speedup = sum_scalar.median().as_secs_f64() / sum_striped.median().as_secs_f64();
    let speedup = filt_speedup.min(sum_speedup);
    println!(
        "filter {filt_speedup:.1}x, group-sum {sum_speedup:.1}x; headline (min) {speedup:.1}x — {}",
        if speedup >= 1.5 {
            "PASS (>= 1.5x on both microbenches)"
        } else {
            "FAIL (< 1.5x acceptance bar)"
        }
    );

    let entries: Vec<(&str, u128)> = vec![
        ("filter-scalar", filt_scalar.median().as_nanos()),
        ("filter-simd", filt_simd.median().as_nanos()),
        ("group-sum-scalar", sum_scalar.median().as_nanos()),
        ("group-sum-striped", sum_striped.median().as_nanos()),
    ];
    let path = write_bench_json("simd_kernels", rows, &entries, speedup).unwrap();
    println!("wrote {}", path.display());
}
