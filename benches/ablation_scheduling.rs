//! §III-A2/A3 ablation: loop-scheduling policies under (a) a uniform
//! cluster, (b) a heterogeneous cluster (two nodes at 1/4 speed — the
//! regime dynamic self-scheduling exists for), and (c) a node failure.
//!
//! Paper claims under test: dynamic schedules balance uneven progress;
//! the hybrid scheme recovers from failure at chunk granularity while a
//! static schedule forces a restart.

use std::sync::Arc;

use forelem::coordinator::{run_job, AggJob, ClusterConfig, Failure};
use forelem::sched::Policy;
use forelem::storage::Table;
use forelem::util::BenchTable;
use forelem::workload::{access_log, AccessLogSpec};

const POLICIES: &[Policy] = &[
    Policy::StaticBlock,
    Policy::FixedChunk(8192),
    Policy::Gss,
    Policy::Trapezoid,
    Policy::Factoring,
    Policy::FeedbackGuided,
    Policy::Hybrid {
        super_chunks_per_worker: 8,
    },
];

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let m = access_log(&AccessLogSpec {
        rows,
        urls: rows / 50,
        skew: 1.1,
        seed: 21,
    });
    let mut t = Table::from_multiset(&m).unwrap();
    t.dict_encode_field(0).unwrap();
    let table = Arc::new(t);
    let workers = 8;
    println!("# §III-A2/3 — scheduling policies ({rows} rows, {workers} workers)");

    // (a) uniform cluster.
    let mut uniform = BenchTable::new("uniform cluster");
    for &p in POLICIES {
        let cfg = ClusterConfig::new(workers, p);
        uniform.row(p.name(), 1, 5, || {
            run_job(&cfg, &AggJob::count(table.clone(), 0)).unwrap()
        });
    }
    uniform.summarize_vs("static");

    // (b) heterogeneous: workers 0,1 run at quarter speed.
    let mut hetero = BenchTable::new("heterogeneous cluster (2 of 8 nodes at 1/4 speed)");
    for &p in POLICIES {
        let cfg = ClusterConfig::new(workers, p).with_slowdown(vec![4.0, 4.0]);
        hetero.row(p.name(), 1, 3, || {
            run_job(&cfg, &AggJob::count(table.clone(), 0)).unwrap()
        });
    }
    hetero.summarize_vs("static");

    // (c) failure of one node at the start.
    let mut fail = BenchTable::new("node 2 fails immediately");
    for &p in POLICIES {
        let cfg = ClusterConfig::new(workers, p).with_failure(Failure {
            worker: 2,
            after_chunks: 0,
        });
        let r = run_job(&cfg, &AggJob::count(table.clone(), 0)).unwrap();
        println!(
            "    {:<12} requeued={} restarts={}",
            p.name(),
            r.metrics.failures_recovered,
            r.metrics.restarts
        );
        fail.row(p.name(), 0, 3, || {
            run_job(&cfg, &AggJob::count(table.clone(), 0)).unwrap()
        });
    }
    fail.summarize_vs("static");
}
