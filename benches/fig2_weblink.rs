//! Figure 2, right group: reverse web-link graph — Hadoop vs forelem
//! variants (see fig2_url_count.rs for methodology). The link table has a
//! genuinely dead field (`source`) so the relayout variant also exercises
//! dead-field elimination.

use std::sync::Arc;

use forelem::coordinator::{run_job, AggJob, ClusterConfig};
use forelem::mapreduce::{self, HadoopConfig, MapFn, MapReduceProgram, ReduceFn};
use forelem::sched::Policy;
use forelem::storage::Table;
use forelem::util::BenchTable;
use forelem::workload::{link_graph, LinkGraphSpec};

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500_000);
    let pages = (rows / 20).max(100);
    let workers = 8;
    println!("# Figure 2 (reverse web-link graph): {rows} edges, {pages} pages, {workers} workers");

    let m = link_graph(&LinkGraphSpec {
        edges: rows,
        pages,
        skew: 1.05,
        seed: 43,
    });
    let table = Table::from_multiset(&m).unwrap();
    let target = 1usize; // (source, target)
    let mut keyed = table.clone();
    keyed.dict_encode_field(target).unwrap();
    // Relayout: dead `source` field elided + integer keyed.
    let relayout = keyed.project(&[target]);
    let table = Arc::new(table);
    let keyed = Arc::new(keyed);
    let relayout = Arc::new(relayout);

    let mr = MapReduceProgram {
        map: MapFn::EmitKeyOne { key_field: target },
        reduce: ReduceFn::CountValues,
    };
    let cluster = ClusterConfig::new(workers, Policy::Gss);

    let mut t = BenchTable::new("reverse web-link graph");
    t.row("hadoop", 0, 2, || {
        mapreduce::run_hadoop(&HadoopConfig::default(), &mr, &table).unwrap()
    });
    t.row("forelem same-data (strings)", 1, 3, || {
        run_job(&cluster, &AggJob::count(table.clone(), target)).unwrap()
    });
    t.row("forelem integer-keyed", 1, 5, || {
        run_job(&cluster, &AggJob::count(keyed.clone(), target)).unwrap()
    });
    t.row("forelem full relayout", 1, 5, || {
        run_job(&cluster, &AggJob::count(relayout.clone(), 0)).unwrap()
    });
    t.summarize_vs("hadoop");
}
