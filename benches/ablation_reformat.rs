//! §III-C1 ablation: when does data reformatting pay off?
//!
//! "if the data is going to be processed multiple times in the future, it
//! will pay off to store the data in a different format." The bench
//! measures raw (strings) vs reformatted (dict-encoded + dead fields
//! elided) execution, charges the one-time encode cost to the reformatted
//! pipeline, and reports the break-even run count — the quantity the
//! compiler's cost gate (transform::reformat::apply_if_profitable)
//! estimates statically. Also covers the compressed-column schemes.

use std::sync::Arc;
use std::time::Instant;

use forelem::coordinator::{run_job, AggJob, ClusterConfig};
use forelem::sched::Policy;
use forelem::storage::{Column, CompressedInts, Table};
use forelem::util::{fmt_duration, BenchTable};
use forelem::workload::{access_log_wide, AccessLogSpec};

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400_000);
    println!("# §III-C1 — data reformatting ({rows} rows, wide schema with dead fields)");

    let m = access_log_wide(&AccessLogSpec {
        rows,
        urls: rows / 20,
        skew: 1.1,
        seed: 3,
    });
    let raw = Arc::new(Table::from_multiset(&m).unwrap());
    let cluster = ClusterConfig::new(8, Policy::Gss);

    // One-time reformat cost (encode + project).
    let t0 = Instant::now();
    let mut keyed = (*raw).clone();
    keyed.dict_encode_field(0).unwrap();
    let reformatted = Arc::new(keyed.project(&[0]));
    let encode_cost = t0.elapsed();

    let mut t = BenchTable::new("URL count per run");
    t.row("raw (strings, wide rows)", 1, 3, || {
        run_job(&cluster, &AggJob::count(raw.clone(), 0)).unwrap()
    });
    t.row("reformatted (int keys, dead fields gone)", 1, 5, || {
        run_job(&cluster, &AggJob::count(reformatted.clone(), 0)).unwrap()
    });
    t.summarize_vs("raw (strings, wide rows)");

    // Break-even analysis.
    let raw_t = t.rows().next().unwrap().1.median().as_secs_f64();
    let ref_t = t.rows().nth(1).unwrap().1.median().as_secs_f64();
    let per_run_saving = raw_t - ref_t;
    let breakeven = (encode_cost.as_secs_f64() / per_run_saving.max(1e-12)).ceil();
    println!(
        "  one-time reformat cost {} → pays off after {} run(s)",
        fmt_duration(encode_cost),
        breakeven
    );
    println!(
        "  memory: raw {} MiB → reformatted {} MiB",
        raw.heap_bytes() >> 20,
        reformatted.heap_bytes() >> 20
    );

    // Compressed-column scheme: the `bytes` payload column under RLE/range.
    let bytes_col = raw.column(2);
    if let Column::Ints(vals) = bytes_col {
        let sorted: Vec<i64> = (0..vals.len() as i64).collect(); // enumerated range column
        let c = CompressedInts::compress(&sorted).unwrap();
        println!(
            "  compressed column scheme: enumerated range column {} MiB → {} bytes",
            (sorted.len() * 8) >> 20,
            c.heap_bytes()
        );
        assert_eq!(c.decompress(), sorted);
    }
}
