//! Perf baseline: morsel-driven parallel scans vs the 1-thread compiled
//! tier on a group-by workload.
//!
//! The query is a guarded group-by (`WHERE bytes >= 0` keeps every row
//! but is a residual predicate, so the per-row register-program body —
//! not the fused whole-loop kernel — runs on the hot path): the shape
//! where morsel parallelism pays most. The acceptance bar is ≥ 2× over
//! the 1-thread compiled tier at 4 threads on 200k rows; the run prints
//! a PASS/FAIL line for it, reports every `sched::Policy` end-to-end,
//! and emits `BENCH_parallel_scan.json` for the CI perf-trajectory
//! artifact. Row count scales via BENCH_ROWS.

use forelem::exec::compile::compile_program;
use forelem::exec::parallel::{run_parallel_compiled, run_parallel_compiled_with_policy};
use forelem::sched::Policy;
use forelem::sql::compile_sql;
use forelem::storage::StorageCatalog;
use forelem::util::{fmt_duration, time_fn, write_bench_json};
use forelem::workload::{access_log_wide, AccessLogSpec};

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    let threads: usize = std::env::var("BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let urls = 512;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "# Morsel-driven parallel scan (guarded group count): {rows} rows, {urls} URLs, \
         {threads} threads on {cores} cores"
    );

    let m = access_log_wide(&AccessLogSpec {
        rows,
        urls,
        skew: 1.1,
        seed: 42,
    });
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("access", &m).unwrap();
    let p = compile_sql(
        "SELECT url, COUNT(url) FROM access WHERE bytes >= 0 GROUP BY url",
        &catalog.schemas(),
    )
    .unwrap();
    let cp = compile_program(&p, &catalog).expect("supported shape");

    // Sanity: the parallel driver agrees with the sequential tier and
    // actually takes the morsel path.
    let seq = run_parallel_compiled(&cp, 1).unwrap();
    let par = run_parallel_compiled(&cp, threads).unwrap();
    assert!(
        par.result().unwrap().bag_eq(seq.result().unwrap()),
        "parallel output diverged from the sequential compiled tier"
    );
    assert!(
        par.stats.idioms.contains(&"vec.morsel".to_string()),
        "morsel driver did not fire: {:?}",
        par.stats.idioms
    );

    let one = time_fn(1, 5, || run_parallel_compiled(&cp, 1).unwrap());
    let many = time_fn(1, 5, || run_parallel_compiled(&cp, threads).unwrap());

    let mrows = rows as f64 / 1e6;
    let throughput = |d: std::time::Duration| mrows / d.as_secs_f64();
    println!(
        "compiled 1 thread        {:>10}  {:>8.2} Mrows/s",
        fmt_duration(one.median()),
        throughput(one.median())
    );
    println!(
        "compiled {threads} threads (gss)  {:>10}  {:>8.2} Mrows/s",
        fmt_duration(many.median()),
        throughput(many.median())
    );

    // Every §III-A2 policy end-to-end at the same thread count.
    let mut medians: Vec<(String, u128)> = vec![
        ("compiled-1-thread".to_string(), one.median().as_nanos()),
        (
            format!("compiled-{threads}-threads-gss"),
            many.median().as_nanos(),
        ),
    ];
    for policy in Policy::ALL {
        let stats = time_fn(1, 3, || {
            run_parallel_compiled_with_policy(&cp, threads, policy).unwrap()
        });
        println!(
            "  sched.{:<14}         {:>10}  {:>8.2} Mrows/s",
            policy.name(),
            fmt_duration(stats.median()),
            throughput(stats.median())
        );
        medians.push((format!("sched-{}", policy.name()), stats.median().as_nanos()));
    }

    let speedup = one.median().as_secs_f64() / many.median().as_secs_f64();
    println!(
        "morsel speedup over 1-thread compiled tier at {threads} threads: {speedup:.1}x — {}",
        if speedup >= 2.0 {
            "PASS (>= 2x)"
        } else {
            "FAIL (< 2x acceptance bar)"
        }
    );

    let entries: Vec<(&str, u128)> = medians.iter().map(|(n, ns)| (n.as_str(), *ns)).collect();
    let path = write_bench_json("parallel_scan", rows, &entries, speedup).unwrap();
    println!("wrote {}", path.display());
}
