//! Perf baseline: interpreter vs vectorized tier vs idiom kernels on the
//! Figure-2 group-count workload (URL access count).
//!
//! Records the throughput ratio future perf PRs (SIMD, morsel-driven
//! scheduling, NUMA partitioning) measure against. The acceptance bar for
//! the vectorized tier is ≥ 3× interpreter throughput at 1M rows; the
//! run prints a PASS/FAIL line for it. Row count scales via BENCH_ROWS.

use forelem::exec;
use forelem::exec::compile::compile_program;
use forelem::sql::compile_sql;
use forelem::storage::StorageCatalog;
use forelem::util::{fmt_duration, time_fn, write_bench_json};
use forelem::workload::{access_log, AccessLogSpec};

fn main() {
    let rows: usize = std::env::var("BENCH_ROWS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let urls = (rows / 20).max(100);
    println!("# Vectorized vs interpreter (Figure-2 group count): {rows} rows, {urls} URLs");

    let m = access_log(&AccessLogSpec {
        rows,
        urls,
        skew: 1.1,
        seed: 42,
    });
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("access", &m).unwrap();
    let p = compile_sql(
        "SELECT url, COUNT(url) FROM access GROUP BY url",
        &catalog.schemas(),
    )
    .unwrap();

    // Sanity: all tiers agree before we time anything.
    let reference = exec::run(&p, &catalog).unwrap();
    let vectorized = exec::run_vectorized(&p, &catalog)
        .unwrap()
        .expect("vectorized tier must support the Figure-2 workload");
    assert!(
        vectorized
            .result()
            .unwrap()
            .bag_eq(reference.result().unwrap()),
        "vectorized output diverged from the interpreter"
    );

    let interp = time_fn(1, 3, || exec::run(&p, &catalog).unwrap());
    let vector = time_fn(1, 5, || {
        exec::run_vectorized(&p, &catalog).unwrap().unwrap()
    });
    let cp = compile_program(&p, &catalog).expect("supported shape");
    let vector_precompiled = time_fn(1, 5, || exec::run_compiled_program(&cp).unwrap());
    let idiom = time_fn(1, 5, || exec::run_compiled(&p, &catalog, None).unwrap());

    let mrows = rows as f64 / 1e6;
    let throughput = |d: std::time::Duration| mrows / d.as_secs_f64();
    println!(
        "interpreter            {:>10}  {:>8.2} Mrows/s",
        fmt_duration(interp.median()),
        throughput(interp.median())
    );
    println!(
        "vectorized             {:>10}  {:>8.2} Mrows/s",
        fmt_duration(vector.median()),
        throughput(vector.median())
    );
    println!(
        "vectorized (precomp)   {:>10}  {:>8.2} Mrows/s",
        fmt_duration(vector_precompiled.median()),
        throughput(vector_precompiled.median())
    );
    println!(
        "idiom kernel           {:>10}  {:>8.2} Mrows/s",
        fmt_duration(idiom.median()),
        throughput(idiom.median())
    );

    let speedup = interp.median().as_secs_f64() / vector.median().as_secs_f64();
    println!(
        "vectorized speedup over interpreter: {speedup:.1}x — {}",
        if speedup >= 3.0 {
            "PASS (>= 3x)"
        } else {
            "FAIL (< 3x acceptance bar)"
        }
    );

    let path = write_bench_json(
        "vectorized_vs_interp",
        rows,
        &[
            ("interpreter", interp.median().as_nanos()),
            ("vectorized", vector.median().as_nanos()),
            ("vectorized-precompiled", vector_precompiled.median().as_nanos()),
            ("idiom-kernel", idiom.median().as_nanos()),
        ],
        speedup,
    )
    .unwrap();
    println!("wrote {}", path.display());
}
