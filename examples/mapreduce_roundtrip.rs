//! §IV genericity: SQL and MapReduce are two front-ends (and MapReduce
//! also a back-end) of the same single intermediate.
//!
//! SQL → forelem IR → derived MapReduce program → re-lowered to the IR,
//! then all three executions compared: the in-process compiled plan, the
//! Hadoop-sim run of the derived program, and the re-lowered IR.
//!
//! Run: cargo run --release --example mapreduce_roundtrip

use forelem::compiler::Engine;
use forelem::ir::{pretty, Value};
use forelem::mapreduce::{self, HadoopConfig};
use forelem::storage::StorageCatalog;
use forelem::workload::{access_log, AccessLogSpec};

fn main() -> anyhow::Result<()> {
    let m = access_log(&AccessLogSpec {
        rows: 50_000,
        urls: 500,
        skew: 1.1,
        seed: 17,
    });
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("access", &m)?;

    let query = "SELECT url, COUNT(url) FROM access GROUP BY url";
    let mut engine = Engine::new(catalog);
    let compiled = engine.compile(query)?;
    println!("— SQL:\n  {query}\n");
    println!("— lowered to the single intermediate:\n{}", pretty::program(&compiled.program));

    // Derive the MapReduce program (§IV).
    let (mr, info) = mapreduce::derive(&compiled.program)?;
    println!("— derived MapReduce program over `{}`:\n{mr}\n", info.table);

    // Re-lower MapReduce → IR (the other direction).
    let schema = engine.catalog.get("access")?.schema.clone();
    let relowered = mapreduce::lower(&mr, &info.table, &schema)?;
    println!("— re-lowered to the intermediate:\n{}", pretty::program(&relowered));

    // Execute all three and compare.
    let direct = engine.execute(&compiled)?;
    let via_ir2 = forelem::exec::run(&relowered, &engine.catalog)?;
    let hadoop = mapreduce::run_hadoop(
        &HadoopConfig::instant(8, 4),
        &mr,
        engine.catalog.get("access")?,
    )?;

    let pairs = |rows: Vec<(String, i64)>| {
        let mut v = rows;
        v.sort();
        v
    };
    let from_multiset = |m: &forelem::ir::Multiset| {
        pairs(
            m.rows()
                .iter()
                .map(|r| (r[0].to_string(), r[1].as_int().unwrap()))
                .collect(),
        )
    };
    let from_hadoop = |p: &[(Value, f64)]| {
        pairs(p.iter().map(|(k, v)| (k.to_string(), *v as i64)).collect())
    };

    let a = from_multiset(direct.result().unwrap());
    let b = from_multiset(via_ir2.result().unwrap());
    let c = from_hadoop(&hadoop.pairs);
    assert_eq!(a, b, "compiled plan vs re-lowered IR");
    assert_eq!(a, c, "compiled plan vs hadoop-sim");
    println!(
        "all three executions agree: {} distinct URLs, {} total accesses",
        a.len(),
        a.iter().map(|(_, n)| n).sum::<i64>()
    );
    Ok(())
}
