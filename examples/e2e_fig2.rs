//! END-TO-END VALIDATION DRIVER (recorded in EXPERIMENTS.md).
//!
//! Reproduces the paper's Figure 2: execution time for the two §IV
//! examples — URL access count and reverse web-link graph — under
//!
//!   1. the Hadoop-like MapReduce baseline (string records, sorted
//!      disk-spilled shuffle, job/task overheads);
//!   2. the forelem pipeline on the SAME (string) input data;
//!   3. the forelem pipeline after the compiler's integer-keying reformat
//!      (§III-C1), with the aggregation routed through the AOT-compiled
//!      XLA artifacts when available;
//!   4. the forelem pipeline after full relayout (dead fields dropped,
//!      integer-keyed, columnar) — the paper's final variant, which it
//!      found adds little beyond integer keying.
//!
//! Every variant's result is checked for exact agreement with the
//! sequential reference interpreter before its time is reported, so this
//! driver proves all layers compose: SQL front-end → IR → transforms →
//! (coordinator over 8 simulated nodes | Hadoop-sim) → XLA kernels.
//!
//! Usage: cargo run --release --example e2e_fig2 [ROWS] [WORKERS]

use std::sync::Arc;
use std::time::Instant;

use forelem::compiler::Engine;
use forelem::coordinator::{AggJob, ClusterConfig};
use forelem::ir::Value;
use forelem::mapreduce::{self, HadoopConfig, MapFn, MapReduceProgram, ReduceFn};
use forelem::runtime::Kernels;
use forelem::sched::Policy;
use forelem::storage::{StorageCatalog, Table};
use forelem::util::fmt_duration;
use forelem::workload::{self, AccessLogSpec, LinkGraphSpec};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(2_000_000);
    let workers: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let keys = (rows / 20).max(100);

    println!("== Figure 2 reproduction: {rows} rows, {keys} distinct keys, {workers} workers ==");
    println!("   (paper: DAS-4, 7 data nodes + master; here: simulated cluster — DESIGN.md §Substitutions)\n");

    let kernels = Kernels::load_default().ok();
    if kernels.is_none() {
        println!("   note: XLA artifacts not found; integer-keyed variant runs native loops\n");
    }

    run_example(
        "URL access count",
        "SELECT url, COUNT(url) FROM access GROUP BY url",
        "access",
        workload::access_log(&AccessLogSpec {
            rows,
            urls: keys,
            skew: 1.1,
            seed: 42,
        }),
        0,
        workers,
        kernels.as_ref(),
    )?;

    run_example(
        "Reverse web-link graph",
        "SELECT target, COUNT(target) FROM links GROUP BY target",
        "links",
        workload::link_graph(&LinkGraphSpec {
            edges: rows,
            pages: keys,
            skew: 1.05,
            seed: 43,
        }),
        1, // target field
        workers,
        kernels.as_ref(),
    )?;

    println!("\nAll variants verified against the sequential reference interpreter.");
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn run_example(
    title: &str,
    query: &str,
    table_name: &str,
    data: forelem::ir::Multiset,
    key_field: usize,
    workers: usize,
    kernels: Option<&Kernels>,
) -> anyhow::Result<()> {
    println!("-- {title} --");
    let table = Table::from_multiset(&data)?;

    // Reference result (sequential oracle, string data).
    let mut catalog = StorageCatalog::new();
    catalog.insert(table_name, table.clone());
    let mut engine = Engine::new(catalog);
    let reference = engine.sql(query)?;
    let ref_result = reference.result().unwrap().clone();
    let expect: std::collections::HashMap<Value, f64> = ref_result
        .rows()
        .iter()
        .map(|r| (r[0].clone(), r[1].as_int().unwrap() as f64))
        .collect();
    let verify = |pairs: &[(Value, f64)], label: &str| {
        assert_eq!(pairs.len(), expect.len(), "{label}: wrong key count");
        for (k, x) in pairs {
            assert_eq!(expect[k], *x, "{label}: key {k}");
        }
    };

    // 1. Hadoop-sim baseline.
    let mr = MapReduceProgram {
        map: MapFn::EmitKeyOne { key_field },
        reduce: ReduceFn::CountValues,
    };
    let h = mapreduce::run_hadoop(&HadoopConfig::default(), &mr, &table)?;
    verify(&h.pairs, "hadoop");
    let hadoop_t = h.metrics.elapsed;
    println!(
        "   hadoop-sim                    {:>12}   (spill {} MiB, {} map + {} reduce tasks)",
        fmt_duration(hadoop_t),
        h.metrics.spill_bytes >> 20,
        h.metrics.map_tasks,
        h.metrics.reduce_tasks
    );

    let cluster = ClusterConfig::new(workers, Policy::Gss);

    // 2. forelem on the same string data.
    let t0 = Instant::now();
    let r = forelem::coordinator::run_job(&cluster, &AggJob::count(Arc::new(table.clone()), key_field))?;
    let strings_t = t0.elapsed();
    verify(&r.pairs, "forelem strings");
    println!(
        "   forelem (same input data)     {:>12}   ({:.1}x vs hadoop)",
        fmt_duration(strings_t),
        hadoop_t.as_secs_f64() / strings_t.as_secs_f64()
    );

    // 3. integer-keyed (§III-C1 reformat; one-time encode cost reported
    //    separately, as the paper assumes data collected in this format).
    let t_enc = Instant::now();
    let mut keyed = table.clone();
    let _dict = keyed.dict_encode_field(key_field)?;
    let encode_t = t_enc.elapsed();
    let keyed = Arc::new(keyed);
    let t0 = Instant::now();
    let job = AggJob::count(keyed.clone(), key_field);
    let r = forelem::coordinator::run_job(&cluster, &job)?;
    let keyed_t = t0.elapsed();
    verify(&r.pairs, "forelem int-keyed");
    println!(
        "   forelem (integer keyed)       {:>12}   ({:.0}x vs hadoop; one-time encode {})",
        fmt_duration(keyed_t),
        hadoop_t.as_secs_f64() / keyed_t.as_secs_f64(),
        fmt_duration(encode_t)
    );

    // 3b. integer-keyed through the XLA artifacts (leader-side kernel).
    if let Some(k) = kernels {
        use forelem::exec::plan::KernelExec;
        let keys: Vec<i64> = keyed.column(key_field).as_int_keys().unwrap();
        let num_keys = keyed.column(key_field).dictionary().unwrap().len();
        if num_keys <= forelem::exec::plan::KERNEL_KEYSPACE {
            let t0 = Instant::now();
            let counts = k.group_count(&keys, num_keys)?;
            let xla_t = t0.elapsed();
            let dict = keyed.column(key_field).dictionary().unwrap();
            let pairs: Vec<(Value, f64)> = counts
                .iter()
                .enumerate()
                .filter(|(_, &c)| c != 0)
                .map(|(i, &c)| (Value::Str(dict.decode(i as u32).unwrap().clone()), c as f64))
                .collect();
            verify(&pairs, "forelem xla");
            println!(
                "   forelem (int keyed, XLA)      {:>12}   ({:.0}x vs hadoop)",
                fmt_duration(xla_t),
                hadoop_t.as_secs_f64() / xla_t.as_secs_f64()
            );
        }
    }

    // 4. full relayout: dead fields elided + integer keyed + columnar.
    //    (For these single-column workloads the paper likewise saw no
    //    further gain beyond integer keying.)
    let relayout = keyed.project(&[key_field.min(keyed.schema.len() - 1)]);
    let t0 = Instant::now();
    let r = forelem::coordinator::run_job(&cluster, &AggJob::count(Arc::new(relayout), 0))?;
    let relayout_t = t0.elapsed();
    verify(&r.pairs, "forelem relayout");
    println!(
        "   forelem (full relayout)       {:>12}   ({:.0}x vs hadoop)",
        fmt_duration(relayout_t),
        hadoop_t.as_secs_f64() / relayout_t.as_secs_f64()
    );
    println!();
    Ok(())
}
