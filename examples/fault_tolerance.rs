//! §III-A3: loop scheduling as the fault-tolerance mechanism.
//!
//! Injects a node failure mid-computation and shows:
//! * static schedule  → whole-job restart (the paper's caveat);
//! * dynamic (GSS)    → only the in-flight chunk is re-queued;
//! * hybrid           → recovery at super-chunk granularity with
//!                      near-static overhead the rest of the time.
//!
//! Run: cargo run --release --example fault_tolerance

use std::sync::Arc;

use forelem::coordinator::{run_job, AggJob, ClusterConfig, Failure};
use forelem::sched::Policy;
use forelem::storage::Table;
use forelem::util::fmt_duration;
use forelem::workload::{access_log, AccessLogSpec};

fn main() -> anyhow::Result<()> {
    let m = access_log(&AccessLogSpec {
        rows: 1_000_000,
        urls: 20_000,
        skew: 1.1,
        seed: 5,
    });
    let mut t = Table::from_multiset(&m)?;
    t.dict_encode_field(0)?;
    let table = Arc::new(t);
    let workers = 8;
    let failure = Failure {
        worker: 3,
        after_chunks: 0,
    };

    println!("== node {} dies after {} completed chunks; {} workers, 1M rows ==\n", failure.worker, failure.after_chunks, workers);
    for policy in [
        Policy::StaticBlock,
        Policy::Gss,
        Policy::Trapezoid,
        Policy::Hybrid {
            super_chunks_per_worker: 8,
        },
    ] {
        let cfg = ClusterConfig::new(workers, policy).with_failure(failure);
        let r = run_job(&cfg, &AggJob::count(table.clone(), 0))?;
        println!(
            "{:<12} {:>12}   chunks={:<4} requeued={} whole-job-restarts={}",
            policy.name(),
            fmt_duration(r.metrics.elapsed),
            r.metrics.chunks,
            r.metrics.failures_recovered,
            r.metrics.restarts,
        );
        // Correctness under failure: every variant counts every row.
        let total: f64 = r.pairs.iter().map(|(_, n)| *n).sum();
        assert_eq!(total as usize, 1_000_000);
    }
    println!("\nEvery policy produced exact counts; they differ only in recovery cost.");
    Ok(())
}
