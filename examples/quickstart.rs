//! Quickstart: SQL → single intermediate → optimized variants → execution.
//!
//! Walks the Figure-1 story: one forelem join spec, two generated
//! evaluation schemes (nested-loops scan vs hash index), same result —
//! plus the group-by pipeline with the optimization trace.
//!
//! Run: cargo run --release --example quickstart

use forelem::compiler::{CompileOptions, Engine, ReformatMode};
use forelem::ir::{pretty, Strategy};
use forelem::prelude::*;
use forelem::storage::StorageCatalog;
use forelem::util::time_fn;

fn main() -> anyhow::Result<()> {
    // ---- build a tiny catalog -------------------------------------------
    let mut catalog = StorageCatalog::new();
    let a = {
        let mut m = Multiset::new(Schema::new(vec![
            ("b_id", DataType::Int),
            ("field", DataType::Str),
        ]));
        for i in 0..20_000i64 {
            m.push(vec![Value::Int(i % 1000), Value::str(format!("a{i}"))]);
        }
        m
    };
    let b = {
        let mut m = Multiset::new(Schema::new(vec![
            ("id", DataType::Int),
            ("field", DataType::Str),
        ]));
        for i in 0..1000i64 {
            m.push(vec![Value::Int(i), Value::str(format!("b{i}"))]);
        }
        m
    };
    catalog.insert_multiset("A", &a)?;
    catalog.insert_multiset("B", &b)?;

    // ---- Figure 1: one spec, two evaluation schemes ----------------------
    let join = "SELECT A.field, B.field FROM A JOIN B ON A.b_id = B.id";
    let mut engine = Engine::new(catalog);
    let compiled = engine.compile(join)?;
    println!("— the single-intermediate spec (Figure 1, top):\n");
    println!("{}", pretty::program(&compiled.program));

    // Force each strategy on the inner index set and time it.
    for strategy in [Strategy::Scan, Strategy::Hash] {
        let mut p = compiled.program.clone();
        if let Stmt::Loop(outer) = &mut p.body[0] {
            if let Stmt::Loop(inner) = &mut outer.body[0] {
                inner.index_set_mut().unwrap().strategy = strategy;
            }
        }
        let catalog = &engine.catalog;
        let stats = time_fn(1, 3, || forelem::exec::run(&p, catalog).unwrap());
        println!(
            "evaluation scheme `{strategy}`: median {}",
            forelem::util::fmt_duration(stats.median())
        );
    }
    println!(
        "(the materialization pass itself chose: {:?})\n",
        inner_strategy(&compiled.program)
    );

    // ---- the §IV group-by pipeline with reformat + parallelization -------
    let mut engine = {
        let mut c = StorageCatalog::new();
        c.insert_multiset(
            "access",
            &forelem::workload::access_log(&forelem::workload::AccessLogSpec {
                rows: 100_000,
                urls: 2_000,
                skew: 1.1,
                seed: 1,
            }),
        )?;
        Engine::new(c).with_options(CompileOptions {
            processors: 4,
            partition_field: None,
            reformat: ReformatMode::Force,
            ..Default::default()
        })
    };
    println!("— URL count, parallelized to 4 processors + integer-keyed:\n");
    println!(
        "{}",
        engine.explain("SELECT url, COUNT(url) FROM access GROUP BY url")?
    );
    let out = engine.sql("SELECT url, COUNT(url) FROM access GROUP BY url")?;
    println!(
        "result: {} distinct URLs, {} rows visited",
        out.result().unwrap().len(),
        out.stats.rows_visited
    );
    Ok(())
}

fn inner_strategy(p: &Program) -> Strategy {
    if let Stmt::Loop(outer) = &p.body[0] {
        if let Stmt::Loop(inner) = &outer.body[0] {
            return inner.index_set().map(|ix| ix.strategy).unwrap_or_default();
        }
    }
    Strategy::Unspecified
}
