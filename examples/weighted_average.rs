//! §III-B vertical integration: the student-grades example.
//!
//! The paper contrasts (a) a query executed by a separate database system
//! whose result set is then consumed by a while-loop, against (b) the
//! vertically integrated form where the data-access loop and the
//! processing loop merge into ONE forelem loop. This example builds both
//! in the IR, shows the merged form equals the staged form, and runs the
//! fold on the AOT-compiled XLA artifact as the L2 path.
//!
//! Run: cargo run --release --example weighted_average

use forelem::ir::pretty;
use forelem::prelude::*;
use forelem::runtime::Kernels;
use forelem::storage::StorageCatalog;

fn main() -> anyhow::Result<()> {
    let mut catalog = StorageCatalog::new();
    let grades = forelem::workload::grades(1000, 8, 7);
    catalog.insert_multiset("Grades", &grades)?;
    let student = 25i64;

    // ---- (a) staged: query materializes a result set, then a loop folds it
    let staged = {
        let mut engine = forelem::compiler::Engine::new(catalog.clone());
        let rows = engine.sql(&format!(
            "SELECT grade, weight FROM Grades WHERE studentID = {student}"
        ))?;
        let result = rows.result().unwrap().clone();
        // ... the application's while-loop over the result set:
        let mut avg = 0.0;
        for r in result.rows() {
            avg += r[0].as_float().unwrap() * r[1].as_float().unwrap();
        }
        println!(
            "staged (query + while loop): {} result rows materialized, avg fold = {avg:.4}",
            result.len()
        );
        avg
    };

    // ---- (b) vertically integrated: the merged forelem loop (§III-B) ----
    let mut p = Program::new("weighted_average")
        .with_relation("Grades", grades.schema.clone())
        .with_scalar("avg", Value::Float(0.0));
    p.body = vec![
        Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::filtered("Grades", "studentID", Expr::int(student)),
            vec![Stmt::assign(
                "avg",
                Expr::add(
                    Expr::var("avg"),
                    Expr::mul(Expr::field("i", "grade"), Expr::field("i", "weight")),
                ),
            )],
        )),
        Stmt::Print {
            format: "Average grade: {}".into(),
            args: vec![Expr::var("avg")],
        },
    ];
    validate(&p)?;
    println!("\nvertically integrated IR (§III-B):\n{}", pretty::program(&p));
    let out = forelem::exec::run(&p, &catalog)?;
    let merged = out.scalars["avg"].as_float().unwrap();
    println!("merged loop result: {merged:.4} (prints: {:?})", out.prints);
    assert!((merged - staged).abs() < 1e-9, "staged and merged diverge");

    // No intermediate result set was materialized: rows_visited only.
    println!(
        "rows visited by the merged loop: {} (no intermediate multiset)",
        out.stats.rows_visited
    );

    // ---- L2 path: the same fold on the XLA artifact ----------------------
    match Kernels::load_default() {
        Ok(k) => {
            // Extract this student's grade/weight vectors (the compiler's
            // generated gather), then fold on the device.
            let t = catalog.get("Grades")?;
            let sid = t.schema.field_id("studentID").unwrap();
            let (mut vs, mut ws) = (Vec::new(), Vec::new());
            for row in 0..t.len() {
                if t.value(row, sid).as_int() == Some(student) {
                    vs.push(t.value(row, 1).as_float().unwrap());
                    ws.push(t.value(row, 2).as_float().unwrap());
                }
            }
            let (dot, wsum) = k.weighted_average(&vs, &ws)?;
            println!(
                "XLA artifact fold: sum(g*w) = {dot:.4}, sum(w) = {wsum:.4}, normalized = {:.4}",
                dot / wsum
            );
            assert!((dot - staged).abs() / staged.abs().max(1.0) < 1e-3);
        }
        Err(e) => println!("(XLA artifacts unavailable: {e})"),
    }
    Ok(())
}
